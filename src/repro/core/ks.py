"""Kolmogorov–Smirnov machinery (§3.2).

Self-contained (no scipy at runtime; tests cross-check against scipy.stats).

The one-sample K-S test compares the empirical CDF of the observed spatial
gaps against a reference CDF.  For the *random* pattern the reference is the
triangular gap law of a uniform-without-replacement (permutation) stream over
[1, c]:

    P(Z = k) = 2 (c - k) / (c (c - 1)),   1 <= k <= c - 1
    F(k)     = 2k/(c-1) - k(k+1)/(c(c-1))

(the distribution of |i - j| for an ordered pair of distinct uniform indices).
"""
from __future__ import annotations

import math
from typing import Callable, Sequence, Tuple

import numpy as np


def triangular_cdf(k: float, c: int) -> float:
    """CDF of the spatial-gap law under the random pattern (eq. 1)."""
    if c < 2:
        return 1.0
    if k < 1:
        return 0.0
    k = min(float(k), float(c - 1))
    kf = math.floor(k)
    return 2.0 * kf / (c - 1) - kf * (kf + 1) / (c * (c - 1.0))


def ecdf_ks_statistic(samples: Sequence[float], cdf: Callable[[float], float]) -> float:
    """D_max = sup_x |ECDF(x) - F(x)| for a one-sample K-S test.

    Uses the standard two-sided evaluation at the order statistics:
    D+ = max(i/n - F(x_i)),  D- = max(F(x_i) - (i-1)/n).
    """
    xs = sorted(samples)
    n = len(xs)
    if n == 0:
        return 0.0
    d = 0.0
    for i, x in enumerate(xs, start=1):
        fx = cdf(x)
        d = max(d, i / n - fx, fx - (i - 1) / n)
    return d


def ks_critical(n: int, alpha: float) -> float:
    """Critical value D_alpha for sample size n at significance alpha.

    Asymptotic (Smirnov) form  D_alpha = sqrt(-ln(alpha/2) / (2 n)),
    with the small-sample correction  sqrt(n) -> sqrt(n) + 0.12 + 0.11/sqrt(n)
    (Stephens 1970), accurate to <1% for n >= 5 — the paper's reference-table
    lookup, in closed form.
    """
    if n <= 0:
        return 1.0
    c_alpha = math.sqrt(-0.5 * math.log(alpha / 2.0))
    sqrt_n = math.sqrt(n)
    return c_alpha / (sqrt_n + 0.12 + 0.11 / sqrt_n)


def ks_pvalue(d: float, n: int) -> float:
    """Two-sided asymptotic p-value via the Kolmogorov distribution tail.

    P(D > d) ~ 2 * sum_{j>=1} (-1)^{j-1} exp(-2 j^2 n d^2).
    """
    if n <= 0 or d <= 0:
        return 1.0
    t = (math.sqrt(n) + 0.12 + 0.11 / math.sqrt(n)) * d
    total = 0.0
    for j in range(1, 101):
        term = (-1.0) ** (j - 1) * math.exp(-2.0 * j * j * t * t)
        total += term
        if abs(term) < 1e-12:
            break
    return max(0.0, min(1.0, 2.0 * total))


def ks_test_random(gaps: Sequence[float], c: int, alpha: float) -> tuple[bool, float, float]:
    """Test H0: 'gaps are drawn from the triangular law over [1, c]'.

    Returns (accept_H0, D_max, D_alpha).  accept_H0=True means the stream is
    consistent with the *random* pattern at significance ``alpha``.
    A zero gap (immediate re-access of the same item) is impossible under H0
    (one access per item per epoch), so zero gaps land below the support and
    inflate D_max naturally via F(0)=0.
    """
    n = len(gaps)
    if n == 0 or c < 3:
        return False, 1.0, 0.0
    d = ecdf_ks_statistic(gaps, lambda k: triangular_cdf(k, c))
    d_alpha = ks_critical(n, alpha)
    return d < d_alpha, d, d_alpha


# ---------------------------------------------------------------------------
# Vectorized (matrix) forms — one K-S test per row, all rows in one shot.
# The scalar functions above are the cross-checked reference (see
# tests/test_equivalence.py); these must agree with them row by row.
# ---------------------------------------------------------------------------

def triangular_cdf_matrix(k: np.ndarray, c: np.ndarray) -> np.ndarray:
    """Elementwise triangular CDF; ``c`` broadcasts per row (shape (R, 1)).

    Mirrors :func:`triangular_cdf`: F = 2k/(c-1) - k(k+1)/(c(c-1)) with k
    clamped to [?, c-1], floored, and F=0 below the support / F=1 for c<2.
    """
    c = c.astype(np.float64)
    kf = np.floor(np.minimum(k.astype(np.float64), c - 1.0))
    with np.errstate(divide="ignore", invalid="ignore"):
        f = 2.0 * kf / (c - 1.0) - kf * (kf + 1.0) / (c * (c - 1.0))
    f = np.where(kf < 1.0, 0.0, f)
    return np.where(c < 2.0, 1.0, f)


def ks_critical_vec(n: np.ndarray, alpha: float) -> np.ndarray:
    """Row-wise Smirnov critical values (same closed form as ks_critical)."""
    n = n.astype(np.float64)
    c_alpha = math.sqrt(-0.5 * math.log(alpha / 2.0))
    with np.errstate(divide="ignore", invalid="ignore"):
        sqrt_n = np.sqrt(n)
        d = c_alpha / (sqrt_n + 0.12 + 0.11 / sqrt_n)
    return np.where(n <= 0, 1.0, d)


def ks_test_random_matrix(abs_gaps: np.ndarray, lengths: np.ndarray,
                          c: np.ndarray, alpha: float
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Matrix form of :func:`ks_test_random`.

    ``abs_gaps`` is an (R, G) matrix of |gap| samples, row r padded beyond
    ``lengths[r]`` with a value larger than any real sample (so the padded
    tail sorts to the end and is masked out).  ``c`` is the per-row index-
    space size.  Returns (accept_H0, D, D_alpha) arrays of shape (R,).

    Row results are independent of the other rows and of the padded width:
    every per-row quantity is either an exact integer count, an elementwise
    float op, or a masked max — no cross-column float accumulation — so a
    window classifies identically whether it rides alone or in a batch.
    """
    R, G = abs_gaps.shape
    srt = np.sort(abs_gaps, axis=1)
    pos = np.arange(1, G + 1, dtype=np.float64)[None, :]
    mask = pos <= lengths[:, None]
    n = lengths.astype(np.float64)[:, None]
    f = triangular_cdf_matrix(srt, c[:, None])
    with np.errstate(divide="ignore", invalid="ignore"):
        d_plus = pos / n - f
        d_minus = f - (pos - 1.0) / n
    dev = np.maximum(d_plus, d_minus)
    dev = np.where(mask, dev, -np.inf)
    d = np.max(dev, axis=1)
    d = np.where(lengths > 0, d, 0.0)
    d_alpha = ks_critical_vec(lengths, alpha)
    accept = (d < d_alpha) & (lengths > 0) & (c >= 3)
    d = np.where((lengths == 0) | (c < 3), 1.0, d)
    d_alpha = np.where((lengths == 0) | (c < 3), 0.0, d_alpha)
    return accept, d, d_alpha


def normal_quantile(p: float) -> float:
    """Inverse standard-normal CDF (Acklam's rational approximation).

    Used by the adaptive-TTL fit (§3.3); |error| < 1.15e-9 over (0,1).
    """
    if not 0.0 < p < 1.0:
        raise ValueError("p must be in (0,1)")
    a = [-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
         1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00]
    b = [-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
         6.680131188771972e+01, -1.328068155288572e+01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
         -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
         3.754408661907416e+00]
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        q = math.sqrt(-2 * math.log(p))
        return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / \
               ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    if p > phigh:
        q = math.sqrt(-2 * math.log(1 - p))
        return -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / \
               ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    q = p - 0.5
    r = q * q
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * q / \
           (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)

"""The unified two-layer cache API: kernel engine + ``CacheClient``.

The paper's engine is a pure observe→recognize→adapt state machine; the
I/O contract around it — who fetches missed bytes, who runs prefetch
candidates, who calls ``complete_prefetch`` when background bytes land —
was re-implemented by every consumer (the cluster simulator's event loop,
the token pipeline's ad-hoc worker thread, raw loops in the examples).
This module absorbs that contract behind one client interface (IGTCache
§2's "no code intrusion" claim; Hoard arXiv:1812.00669 draws the same
line between cache kernel and client library).

Two layers:

**Kernel layer** — the engine itself (``IGTCache`` / ``ShardedIGTCache``),
a deterministic single-threaded state machine with the documented surface

    read / read_batch / complete_prefetch / cancel_prefetch / tick /
    pin / never_cache / stats / hit_ratio / snapshot / iter_workload_cmus

The kernel never does I/O, never imports the storage layer, and never
owns time: every call takes ``now``.  This is the property-test surface
(tests/test_equivalence.py) and stays available for callers that need
full control (the discrete-event simulator owns bandwidth, so it drives
the kernel through the client with a link-backed executor; see
``sim.cluster.LinkExecutor``).

**Client layer** — ``CacheClient`` wraps a kernel with

  * a pluggable backing store on the **v2 storage protocol**
    (``storage.api.BackingStore``: ``fetch_range`` / ``fetch_many`` /
    ``capabilities``) that supplies actual bytes — partial-extent reads
    fetch exact sub-block ranges instead of over-fetching whole blocks,
    and batched reads funnel their demand misses through one
    ``fetch_many`` call; legacy one-method ``fetch_block`` stores are
    adapted transparently (``storage.api.as_backing_store``);
  * a :class:`RetryPolicy`-guarded fetch path: transient store errors
    (``storage.api.TransientStoreError``) retry with bounded backoff,
    permanent errors propagate to the blocked reader and *cancel* the
    affected prefetch candidates on the kernel, so the executor identity
    ``submitted == completed + cancelled + deduped`` and the kernel
    pending table survive a failing backend;
  * a :class:`PrefetchExecutor` that runs the kernel's prefetch
    candidates: the deterministic inline :class:`SimExecutor` (virtual
    clock; bitwise-equivalent to the caller-driven loop) or the
    :class:`ThreadedExecutor` (one worker per kernel shard — shards share
    no read-path state — bounded queues, demand-miss > prefetch priority,
    in-queue dedup, and cancellation that calls ``cancel_prefetch`` on
    overflow/shutdown instead of silently dropping candidates).

``open_cache(store_or_uri, capacity, ...) -> CacheClient`` is the one
constructor path all consumers share; ``store`` may be a store instance
or a URI for the scheme registry (``"sim://default"``,
``"file:///data"``, ``"mem://"``, ``"faulty+sim://..."`` — see
``storage.api.open_store``).  Every future scaling lever (multi-process
shards, S3/GCS adapters) plugs in behind these two protocols.  See
docs/API.md for the full contract.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import (Callable, Deque, Dict, List, Optional, Sequence, Set,
                    Tuple, Union)

import numpy as np

from .cache import path_key
from .faults import SHARD_UP, ShardUnavailableError
from .igtcache import BlockResult, EngineOptions, ReadOutcome
from .sharded import Engine, ShardedIGTCache, make_engine
from .types import CacheConfig, PathT, block_key

__all__ = [
    "BackingStore", "CacheClient", "ClientStats", "ExecutorStats",
    "KernelGuard", "NullExecutor", "PrefetchExecutor", "ReadResult",
    "SimExecutor", "ThreadedExecutor", "open_cache",
]

# One demand fetch: (file-or-block path, offset within it, length) — the
# same shape as storage.api.RangeRequest (kept structural so the kernel
# package does not import the storage package at import time).
RangeRequest = Tuple[PathT, int, int]


class BackingStore:
    """Legacy (v1) byte-source protocol: one method
    ``fetch_block(block_path, size) -> np.ndarray[uint8]`` returning the
    first ``size`` bytes of the block at ``block_path``.

    Kept for third-party stores written against the PR-3 API — the
    client adapts them via ``storage.api.as_backing_store``.  New
    backends should implement the ranged/batched v2 protocol
    (``storage.api.BackingStore``) instead.
    """

    def fetch_block(self, block_path: PathT,
                    size: int) -> np.ndarray:  # pragma: no cover - protocol
        raise NotImplementedError


@dataclass
class ExecutorStats:
    """Candidate accounting for one executor (lost-candidate audit trail:
    ``submitted == completed + cancelled + deduped + in_flight``)."""

    submitted: int = 0        # candidates handed to submit()
    completed: int = 0        # complete_prefetch delivered to the kernel
    cancelled: int = 0        # cancel_prefetch on overflow/shutdown/failure
    deduped: int = 0          # dropped: same block already queued/in flight
    demand_fetches: int = 0   # priority demand-miss range fetches served
    retries: int = 0          # transient store errors absorbed by RetryPolicy
    fetch_errors: int = 0     # fetches that failed past the retry bound

    def snapshot(self) -> dict:
        return {"submitted": self.submitted, "completed": self.completed,
                "cancelled": self.cancelled, "deduped": self.deduped,
                "demand_fetches": self.demand_fetches,
                "retries": self.retries, "fetch_errors": self.fetch_errors}


@dataclass
class ClientStats:
    """Degraded-path accounting for one :class:`CacheClient`.

    Counts reads the client served *around* the kernel while a shard was
    down/restarting (bytes came straight from the backing store, no
    cache observation happened) — the availability cost a fault leaves
    behind.  ``fallback_fetches`` counts demand fetches that started on
    the executor and finished on the store after the shard died between
    the kernel read and the byte fetch."""

    degraded_reads: int = 0       # read requests served without the kernel
    degraded_bytes: int = 0       # bytes fetched via the degraded path
    fallback_fetches: int = 0     # executor demand fetches re-run direct

    def snapshot(self) -> dict:
        return {"degraded_reads": self.degraded_reads,
                "degraded_bytes": self.degraded_bytes,
                "fallback_fetches": self.fallback_fetches}


class KernelGuard:
    """Per-shard mutual exclusion for the kernel.

    The kernel is a single-threaded state machine; a ``ShardedIGTCache``
    is N independent ones (shards share no read-path state, so per-shard
    locks give shard-parallel readers/completers).  Cross-shard
    operations (``tick`` with the global rebalancer, ``pin``) take all
    locks in index order.  For a plain ``IGTCache`` there is one lock.
    """

    def __init__(self, engine: Engine) -> None:
        self.engine = engine
        # duck-typed: any sharded driver (in-process facade or the
        # multi-process ProcessShardedCache) exposes n_shards + shard_id
        n = getattr(engine, "n_shards", 1)
        self._locks = [threading.Lock() for _ in range(n)]
        self._sharded = n > 1

    @property
    def n_shards(self) -> int:
        return len(self._locks)

    def shard_id(self, path: PathT) -> int:
        if not self._sharded:
            return 0
        return self.engine.shard_id(path)

    def lock_for(self, path: PathT) -> threading.Lock:
        return self._locks[self.shard_id(path)]

    def lock_shard(self, sid: int) -> threading.Lock:
        return self._locks[sid]

    def acquire_all(self) -> None:
        for lk in self._locks:          # fixed order: no deadlock
            lk.acquire()

    def release_all(self) -> None:
        for lk in reversed(self._locks):
            lk.release()


class PrefetchExecutor:
    """Protocol + shared plumbing for prefetch candidate execution.

    Lifecycle: constructed unattached (configuration only), then
    ``attach``-ed exactly once by the :class:`CacheClient` that owns it.
    ``submit`` receives the candidates of one read at timestamp ``now``;
    the executor must eventually either ``complete_prefetch`` or
    ``cancel_prefetch`` every candidate on the kernel — never drop one
    silently (the kernel tracks pending candidates for dedup, so a
    dropped candidate blocks that block's re-issue forever).  A fetch
    that fails past the retry bound counts as ``cancel``, keeping the
    identity intact under a failing backend.
    """

    def __init__(self) -> None:
        self.stats = ExecutorStats()
        self.engine: Optional[Engine] = None
        self.backing = None               # storage.api.BackingStore or None
        self.guard: Optional[KernelGuard] = None
        self.clock: Callable[[], float] = time.monotonic
        self.retry = None                 # storage.api.RetryPolicy
        self._stats_lock = threading.Lock()

    def attach(self, engine: Engine, backing, guard: KernelGuard,
               clock: Callable[[], float], retry=None) -> None:
        if self.engine is not None and self.engine is not engine:
            raise RuntimeError("executor is already attached to a kernel")
        self.engine = engine
        self.backing = backing
        self.guard = guard
        self.clock = clock
        if retry is not None:
            self.retry = retry
        elif self.retry is None:
            from ..storage.api import RetryPolicy
            self.retry = RetryPolicy()

    # -- candidate path -----------------------------------------------------
    def submit(self, candidates: Sequence[Tuple[PathT, int]],
               now: float) -> None:  # pragma: no cover - protocol
        raise NotImplementedError

    # -- fetch plumbing -----------------------------------------------------
    def _note_retry(self, attempt: int, exc: BaseException) -> None:
        with self._stats_lock:
            self.stats.retries += 1

    def fetch_ranges(self, requests: Sequence[RangeRequest]
                     ) -> List[np.ndarray]:
        """Retry-guarded raw range fetch (one ``fetch_many`` call)."""
        assert self.backing is not None, "byte fetch needs a backing store"
        try:
            return self.retry.call(self.backing.fetch_many, requests,
                                   on_retry=self._note_retry)
        except BaseException:
            with self._stats_lock:
                self.stats.fetch_errors += 1
            raise

    # -- demand path (priority over prefetch) -------------------------------
    def fetch_demand(self, requests: Sequence[RangeRequest]
                     ) -> List[np.ndarray]:
        """Fetch demand-missed ranges; must preempt queued prefetches."""
        with self._stats_lock:
            self.stats.demand_fetches += len(requests)
        return self.fetch_ranges(requests)

    # -- lifecycle ----------------------------------------------------------
    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted candidate completed or cancelled."""
        return True

    def close(self, cancel_pending: bool = True) -> None:
        pass


class SimExecutor(PrefetchExecutor):
    """Deterministic inline executor for virtual-clock callers.

    ``submit`` completes every candidate synchronously at the read's own
    ``now`` — exactly the caller-driven loop the discrete-event tests and
    the non-threaded pipeline ran by hand, so a client with a SimExecutor
    is bitwise-equivalent to that loop (pinned in
    tests/test_equivalence.py).  ``max_fetch_bytes=0`` (default) moves no
    bytes: pure-simulation callers only track sizes and latencies.  A
    candidate whose (capped) fetch fails past the retry bound is
    cancelled on the kernel instead of completed.
    """

    def __init__(self, max_fetch_bytes: int = 0) -> None:
        super().__init__()
        self.max_fetch_bytes = max_fetch_bytes

    def submit(self, candidates: Sequence[Tuple[PathT, int]],
               now: float) -> None:
        if not candidates:
            return
        self.stats.submitted += len(candidates)
        eng = self.engine
        for path, size in candidates:
            if self.backing is not None and self.max_fetch_bytes > 0:
                try:
                    self.retry.call(self.backing.fetch_range, path, 0,
                                    min(size, self.max_fetch_bytes),
                                    on_retry=self._note_retry)
                except Exception:
                    self.stats.fetch_errors += 1
                    eng.cancel_prefetch(path)
                    self.stats.cancelled += 1
                    continue
            eng.complete_prefetch(path, size, now)
            self.stats.completed += 1


class NullExecutor(PrefetchExecutor):
    """Read-only client: every candidate is cancelled immediately (the
    kernel's pending-table stays clean; nothing is fetched)."""

    def submit(self, candidates: Sequence[Tuple[PathT, int]],
               now: float) -> None:
        if not candidates:
            return
        self.stats.submitted += len(candidates)
        for path, _size in candidates:
            self.engine.cancel_prefetch(path)
            self.stats.cancelled += 1


class _DemandBatch:
    """One shard's slice of a demand fetch: served by that shard's worker
    in a single ``fetch_many`` call (shard-parallel batched fetches)."""

    __slots__ = ("requests", "results", "error", "event")

    def __init__(self, requests: List[RangeRequest]) -> None:
        self.requests = requests
        self.results: Optional[List[np.ndarray]] = None
        self.error: Optional[BaseException] = None
        self.event = threading.Event()


class _ShardQueue:
    """Two-class bounded queue for one shard worker.

    Demand batches (missed ranges a reader is blocked on) always pop
    before background prefetch candidates and are never rejected; the
    background class is bounded by ``depth`` and rejects on overflow (the
    caller cancels the candidate on the kernel).  ``keys`` is the
    in-queue / in-flight dedup set for background candidates.
    """

    def __init__(self, depth: int) -> None:
        self.depth = depth
        self.cv = threading.Condition()
        self.demand: Deque[_DemandBatch] = deque()
        self.background: Deque[Tuple[PathT, int, str]] = deque()
        self.keys: Set[str] = set()          # queued + in-flight candidates
        self.outstanding = 0                 # background items not yet done
        self.closed = False

    def put_demand(self, item: _DemandBatch) -> bool:
        with self.cv:
            if self.closed:
                return False
            self.demand.append(item)
            self.cv.notify()
            return True

    def offer_background(self, path: PathT, size: int,
                         key: str) -> str:
        """Returns 'queued' | 'dup' | 'full' | 'closed'."""
        with self.cv:
            if self.closed:
                return "closed"
            if key in self.keys:
                return "dup"
            if len(self.background) >= self.depth:
                return "full"
            self.keys.add(key)
            self.background.append((path, size, key))
            self.outstanding += 1
            self.cv.notify()
            return "queued"

    def get(self, timeout: float):
        with self.cv:
            if not self.demand and not self.background:
                self.cv.wait(timeout)
            if self.demand:
                return self.demand.popleft()
            if self.background:
                return self.background.popleft()
            return None

    def task_done(self, key: str) -> None:
        with self.cv:
            self.keys.discard(key)
            self.outstanding -= 1
            self.cv.notify_all()

    def drain_background(self) -> List[Tuple[PathT, int, str]]:
        with self.cv:
            items = list(self.background)
            self.background.clear()
            for _, _, key in items:
                self.keys.discard(key)
                self.outstanding -= 1
            self.cv.notify_all()
            return items

    def wait_idle(self, timeout: Optional[float]) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self.cv:
            while self.outstanding > 0 or self.demand:
                if self.closed:
                    # a closed queue can only drain via close()'s own
                    # cancellation sweep — report the truth promptly
                    # instead of burning the caller's full timeout
                    return False
                rem = None if deadline is None else deadline - time.monotonic()
                if rem is not None and rem <= 0:
                    return False
                self.cv.wait(rem if rem is not None else 0.1)
        return True


class ThreadedExecutor(PrefetchExecutor):
    """Per-shard background prefetch workers.

    One daemon worker per kernel shard (``IGTCache`` counts as one
    shard); a candidate is routed to its block's shard worker, so
    completions only ever contend with reads of the same shard — the
    multi-worker shard driver from the ROADMAP.  Per-shard queues are
    bounded; an overflowing candidate is *cancelled on the kernel*
    (``cancel_prefetch``) so the pending-table never leaks, and shutdown
    cancels everything still queued.  Demand-miss fetches jump every
    queue (strict priority), are never rejected, and arrive as per-shard
    batches served in one ``fetch_many`` call each.  Background fetches
    ride the client's :class:`RetryPolicy`; a fetch that still fails is
    cancelled on the kernel — the worker survives a failing backend.
    """

    def __init__(self, queue_depth: int = 4096,
                 max_fetch_bytes: int = 4096,
                 poll_s: float = 0.05) -> None:
        super().__init__()
        self.queue_depth = queue_depth
        self.max_fetch_bytes = max_fetch_bytes
        self.poll_s = poll_s
        self._queues: List[_ShardQueue] = []
        self._workers: List[threading.Thread] = []
        self._stop = threading.Event()
        self._started = False
        self._closed = False

    # -- lifecycle ----------------------------------------------------------
    def attach(self, engine: Engine, backing, guard: KernelGuard,
               clock: Callable[[], float], retry=None) -> None:
        super().attach(engine, backing, guard, clock, retry)
        if self._started:
            return
        self._started = True
        for sid in range(guard.n_shards):
            q = _ShardQueue(self.queue_depth)
            w = threading.Thread(target=self._run, args=(sid, q),
                                 name=f"igt-prefetch-{sid}", daemon=True)
            self._queues.append(q)
            self._workers.append(w)
            w.start()

    def close(self, cancel_pending: bool = True) -> None:
        self._closed = True             # submit() now raises, not enqueues
        if not self._started or self._stop.is_set():
            return
        if not cancel_pending:
            self.flush()
        for q in self._queues:          # late offers now reject as 'closed'
            with q.cv:
                q.closed = True
        self._cancel_queued()
        self._stop.set()
        for w in self._workers:
            w.join(timeout=2.0)
        # workers are down: anything that slipped between drain and join is
        # cancelled too — a candidate must never be dropped silently —
        # and stranded demand waiters are released with an error
        self._cancel_queued()
        for q in self._queues:
            with q.cv:
                while q.demand:
                    item = q.demand.popleft()
                    item.error = RuntimeError(
                        "ThreadedExecutor closed with the fetch in queue")
                    item.event.set()

    def _cancel_queued(self) -> None:
        for sid, q in enumerate(self._queues):
            for path, _size, _key in q.drain_background():
                with self.guard.lock_shard(sid):
                    self.engine.cancel_prefetch(path)
                with self._stats_lock:
                    self.stats.cancelled += 1

    def flush(self, timeout: Optional[float] = None) -> bool:
        return all(q.wait_idle(timeout) for q in self._queues)

    # -- candidate path -----------------------------------------------------
    def submit(self, candidates: Sequence[Tuple[PathT, int]],
               now: float) -> None:
        if not candidates:
            return
        guard = self.guard
        if self._closed:
            # close-vs-submit race: the queues are dead, so first release
            # every candidate on the kernel (the pending table must never
            # leak), then fail loudly — a silent cancel here would let a
            # caller keep feeding a closed executor forever
            with self._stats_lock:
                self.stats.submitted += len(candidates)
            for path, _size in candidates:
                with guard.lock_for(path):
                    self.engine.cancel_prefetch(path)
                with self._stats_lock:
                    self.stats.cancelled += 1
            raise RuntimeError("submit() on a closed ThreadedExecutor")
        with self._stats_lock:
            self.stats.submitted += len(candidates)
        for path, size in candidates:
            sid = guard.shard_id(path)
            got = self._queues[sid].offer_background(path, size,
                                                     path_key(path))
            if got == "queued":
                continue
            if got == "dup":
                # same block already queued/in flight: this duplicate
                # candidate will never get its own completion — release it
                with guard.lock_shard(sid):
                    self.engine.cancel_prefetch(path)
                with self._stats_lock:
                    self.stats.deduped += 1
            else:  # full / closed → cancel instead of silently dropping
                with guard.lock_shard(sid):
                    self.engine.cancel_prefetch(path)
                with self._stats_lock:
                    self.stats.cancelled += 1

    # -- demand path --------------------------------------------------------
    def fetch_demand(self, requests: Sequence[RangeRequest]
                     ) -> List[np.ndarray]:
        """Split the demand ranges by shard, hand each shard worker its
        slice as one priority batch (served via a single ``fetch_many``),
        and block until every slice lands — misses of one read/batch
        fetch shard-parallel."""
        assert self.backing is not None, "demand fetch needs a backing store"
        with self._stats_lock:
            self.stats.demand_fetches += len(requests)
        by_shard: Dict[int, List[int]] = {}
        for i, req in enumerate(requests):
            by_shard.setdefault(self.guard.shard_id(req[0]), []).append(i)
        batches: List[Tuple[List[int], _DemandBatch]] = []
        for sid, idxs in by_shard.items():
            batch = _DemandBatch([requests[i] for i in idxs])
            batches.append((idxs, batch))
            if not self._queues[sid].put_demand(batch):
                batch.error = RuntimeError(
                    "demand fetch on a closed ThreadedExecutor")
                batch.event.set()
        for _idxs, batch in batches:
            batch.event.wait()
        out: List[Optional[np.ndarray]] = [None] * len(requests)
        for idxs, batch in batches:
            if batch.error is not None:  # re-raise in the reader's thread
                raise batch.error
            for i, data in zip(idxs, batch.results):
                out[i] = data
        return out  # type: ignore[return-value]

    # -- worker loop --------------------------------------------------------
    def _run(self, sid: int, q: _ShardQueue) -> None:
        guard = self.guard
        while not self._stop.is_set():
            got = q.get(self.poll_s)
            if got is None:
                continue
            if isinstance(got, _DemandBatch):
                # a failing backing store must not kill the shard worker
                # or strand the blocked reader: hand the error back
                # through the batch (fetch_ranges already retried
                # transient errors per the RetryPolicy)
                try:
                    got.results = self.fetch_ranges(got.requests)
                except BaseException as e:
                    got.error = e
                finally:
                    got.event.set()
                    with q.cv:
                        q.cv.notify_all()
                continue
            path, size, key = got
            try:
                try:
                    if self.backing is not None and self.max_fetch_bytes > 0:
                        # the actual byte movement (capped: content is what
                        # a real store would stream; the kernel only needs
                        # sizes), transient failures retried
                        self.retry.call(
                            self.backing.fetch_range, path, 0,
                            min(size, self.max_fetch_bytes),
                            on_retry=self._note_retry)
                    with guard.lock_shard(sid):
                        self.engine.complete_prefetch(path, size,
                                                      self.clock())
                    with self._stats_lock:
                        self.stats.completed += 1
                except Exception:
                    # failed past the retry bound → the candidate will
                    # never complete: release it on the kernel, keep the
                    # worker alive
                    with self._stats_lock:
                        self.stats.fetch_errors += 1
                    with guard.lock_shard(sid):
                        self.engine.cancel_prefetch(path)
                    with self._stats_lock:
                        self.stats.cancelled += 1
            finally:
                q.task_done(key)


class ReadResult:
    """One client read: the kernel's per-block outcome plus, when the
    client fetched through its backing store, the requested bytes."""

    __slots__ = ("outcome", "data")

    def __init__(self, outcome: ReadOutcome,
                 data: Optional[np.ndarray] = None) -> None:
        self.outcome = outcome
        self.data = data

    @property
    def blocks(self):
        return self.outcome.blocks

    @property
    def cached_bytes(self) -> int:
        return self.outcome.cached_bytes

    @property
    def remote_bytes(self) -> int:
        return self.outcome.remote_bytes


def _sync_block_size(store, cfg: Optional[CacheConfig]) -> None:
    """Align a store's block geometry with the cache config (walking
    wrapper ``inner`` chains, e.g. ``faulty+file://``).  Only objects
    whose *class* declares an integer ``block_size`` are touched —
    ``__getattr__``-delegating wrappers are skipped in favor of the
    store they wrap, and property-backed geometries are left alone."""
    if cfg is None:
        return
    obj, hops = store, 0
    while obj is not None and hops < 4:
        if (isinstance(getattr(type(obj), "block_size", None), int)
                and obj.block_size != cfg.block_size):
            obj.block_size = cfg.block_size
        obj = obj.__dict__.get("inner") if hasattr(obj, "__dict__") else None
        hops += 1


class CacheClient:
    """The caller layer: reads + prefetch execution over one kernel.

    ``read``/``read_batch`` serve through the kernel under the shard
    guard, hand the kernel's prefetch candidates to the executor, and —
    when asked for bytes — fetch hits locally (exact sub-block ranges)
    and misses through the executor's priority demand path
    (shard-parallel ``fetch_many`` batches under the ThreadedExecutor).
    All kernel introspection (``stats``, ``snapshot``,
    ``iter_workload_cmus``) passes through.

    ``backing`` accepts anything ``storage.api.as_backing_store``
    understands: a v2 store, a legacy one-method ``fetch_block`` store
    (adapted), or ``None`` for metadata-only clients.

    **Degraded-mode reads** (``degraded=True``, the default): when the
    kernel raises :class:`ShardUnavailableError` — a shard worker of the
    multi-process driver died, is restarting, or exhausted its restart
    budget — the client serves the affected requests *around* the
    kernel: it synthesizes an all-miss outcome from the store's file
    geometry and fetches the bytes straight from the backing store, so
    callers always get correct bytes and never hang on a dead worker.
    Only the failed sub-batch degrades; outcomes the surviving shards
    already produced are kept (re-reading would double-observe their
    keys).  Degraded traffic is counted in :class:`ClientStats`.  The
    only error a reader sees is the backing store itself permanently
    failing.  ``breaker`` (a ``storage.api.CircuitBreaker``) optionally
    guards every client-side byte fetch against a store that is failing
    hard: after K consecutive transient failures calls fast-fail with
    ``CircuitOpenError`` until the breaker half-opens.

    Time: pass ``now`` explicitly (virtual-clock callers) or omit it to
    use the client's ``clock`` (default ``time.monotonic``).
    """

    def __init__(self, engine: Engine, *,
                 backing=None,
                 executor: Optional[PrefetchExecutor] = None,
                 clock: Optional[Callable[[], float]] = None,
                 fetch_bytes: bool = False,
                 retry=None,
                 degraded: bool = True,
                 breaker=None) -> None:
        from ..storage.api import RetryPolicy, as_backing_store
        self.engine = engine
        self.backing = as_backing_store(backing)
        # one block geometry everywhere: the kernel plans block paths
        # with cfg.block_size, and stores resolve "#b" leaves with their
        # own block_size — a mismatch would silently return wrong bytes
        _sync_block_size(engine.meta, engine.cfg)
        _sync_block_size(self.backing, engine.cfg)
        self.breaker = breaker
        if retry is not None:
            self.retry = retry
        elif breaker is not None:
            # the default policy adopts the breaker so *every* fetch
            # path (executor workers included) rides it
            self.retry = RetryPolicy(breaker=breaker)
        else:
            self.retry = RetryPolicy()
        self.degraded = degraded
        self.client_stats = ClientStats()
        self._cstats_lock = threading.Lock()
        self.clock = clock or time.monotonic
        self.guard = KernelGuard(engine)
        self.executor = executor if executor is not None else SimExecutor()
        self.executor.attach(engine, self.backing, self.guard, self.clock,
                             self.retry)
        self.fetch_bytes = fetch_bytes
        if fetch_bytes and self.backing is None:
            raise ValueError("fetch_bytes=True needs a backing store")
        self._closed = False
        # open_cache sets this: a client that *constructed* its engine
        # also shuts it down (process-backed drivers own OS resources)
        self._own_engine = False

    # ------------------------------------------------------------------ read
    def read(self, file_path: PathT, offset: int, size: int,
             now: Optional[float] = None, *,
             fetch: Optional[bool] = None) -> ReadResult:
        """Serve one extent: kernel read → executor-dispatched prefetch →
        (optionally) bytes for the requested range.  A dead shard
        degrades to a direct store fetch instead of raising (see the
        class docstring)."""
        if now is None:
            now = self.clock()
        degraded = False
        try:
            with self.guard.lock_for(file_path):
                out = self.engine.read(file_path, offset, size, now)
        except ShardUnavailableError:
            if not self.degraded:
                raise
            out = self._degraded_outcome(file_path, offset, size)
            degraded = True
            with self._cstats_lock:
                self.client_stats.degraded_reads += 1
        if out.prefetches:
            self.executor.submit(out.prefetches, now)
        want = self.fetch_bytes if fetch is None else fetch
        if not want or not out.blocks:
            return ReadResult(out)
        self._require_backing()
        plan = self._plan_ranges(file_path, offset, size, out)
        fetched: Dict[RangeRequest, np.ndarray] = {}
        demand = [r for r, hit in plan if not hit]
        if demand:
            fetched.update(zip(demand,
                               self._fetch_misses(demand, degraded)))
        self._fetch_hits([plan], fetched)
        return ReadResult(out, self._assemble(plan, fetched))

    def read_batch(self, requests: Sequence[Tuple[PathT, int, int]],
                   now: Optional[float] = None, *,
                   fetch: Optional[bool] = None) -> List[ReadResult]:
        """One kernel ``read_batch`` (tick amortized per batch), prefetch
        dispatch per outcome — and, when fetching bytes, *all* demand
        misses of the batch funneled through one ``fetch_demand`` call
        (one ``fetch_many`` per shard under the ThreadedExecutor).  When
        a shard is down only its sub-batch degrades to direct store
        fetches; the surviving shards' outcomes are kept as-is."""
        if now is None:
            now = self.clock()
        requests = list(requests)
        degraded_idx: Set[int] = set()
        self.guard.acquire_all()
        try:
            outs = self.engine.read_batch(requests, now)
        except ShardUnavailableError as e:
            if not self.degraded:
                raise
            # patch only the holes: the error carries the healthy
            # shards' outcomes, and re-issuing them would double-observe
            partial = (e.partial if e.partial is not None
                       else [None] * len(requests))
            holes = (e.indices if e.indices is not None
                     else [i for i, o in enumerate(partial) if o is None])
            outs = list(partial)
            for i in holes:
                fp, off, sz = requests[i]
                outs[i] = self._degraded_outcome(fp, off, sz)
                degraded_idx.add(i)
            with self._cstats_lock:
                self.client_stats.degraded_reads += len(degraded_idx)
        finally:
            self.guard.release_all()
        for out in outs:
            if out.prefetches:
                self.executor.submit(out.prefetches, now)
        want = self.fetch_bytes if fetch is None else fetch
        if not want:
            return [ReadResult(out) for out in outs]
        self._require_backing()
        plans = [self._plan_ranges(fp, off, sz, out) if out.blocks else []
                 for (fp, off, sz), out in zip(requests, outs)]
        all_demand: List[RangeRequest] = []
        direct_demand: List[RangeRequest] = []
        seen: Set[RangeRequest] = set()
        for j, plan in enumerate(plans):
            for r, hit in plan:
                if not hit and r not in seen:
                    seen.add(r)
                    # a degraded request's shard is dead: its misses
                    # must not travel through the executor's worker RPC
                    (direct_demand if j in degraded_idx
                     else all_demand).append(r)
        fetched: Dict[RangeRequest, np.ndarray] = {}
        if all_demand:
            fetched.update(zip(all_demand,
                               self._fetch_misses(all_demand, False)))
        if direct_demand:
            fetched.update(zip(direct_demand,
                               self._fetch_misses(direct_demand, True)))
        self._fetch_hits(plans, fetched)
        return [ReadResult(out,
                           self._assemble(plan, fetched) if plan else None)
                for out, plan in zip(outs, plans)]

    # ------------------------------------------------------- degraded path
    def _degraded_outcome(self, file_path: PathT, offset: int,
                          size: int) -> ReadOutcome:
        """All-miss outcome for a request whose shard kernel is gone,
        built from the store's file geometry (clamped to EOF) — the same
        block decomposition the kernel would have produced, minus any
        caching/prefetching (the kernel never saw the access)."""
        bs = self.engine.cfg.block_size
        try:
            fsize = self.engine.meta.file_size(file_path)
        except Exception:
            fsize = offset + size    # unknown geometry: trust the request
        end = min(offset + size, fsize)
        blocks: List[BlockResult] = []
        if end > offset:
            first = offset // bs
            for b in range(first, (end - 1) // bs + 1):
                blocks.append(BlockResult(
                    path_key(block_key(file_path, b)),
                    min(bs, fsize - b * bs), False))
        return ReadOutcome(blocks, [])

    def _direct_fetch(self, requests: Sequence[RangeRequest]
                      ) -> List[np.ndarray]:
        """Degraded byte path: straight to the backing store, bypassing
        the executor (whose demand path would RPC the dead worker).
        Retry-guarded and breaker-guarded like every other fetch."""
        if self.breaker is not None:
            data = self.retry.call(self.backing.fetch_many, list(requests),
                                   breaker=self.breaker)
        else:   # a caller-supplied policy may not take the breaker kwarg
            data = self.retry.call(self.backing.fetch_many, list(requests))
        with self._cstats_lock:
            self.client_stats.degraded_bytes += sum(r[2] for r in requests)
        return data

    def _fetch_misses(self, demand: List[RangeRequest],
                      degraded: bool) -> List[np.ndarray]:
        """Demand misses via the executor — or, for degraded requests /
        a shard that died after the kernel read, direct from the store
        so the blocked reader still gets its bytes."""
        if degraded:
            return self._direct_fetch(demand)
        try:
            return self.executor.fetch_demand(demand)
        except ShardUnavailableError:
            if not self.degraded:
                raise
            with self._cstats_lock:
                self.client_stats.fallback_fetches += 1
            return self._direct_fetch(demand)

    # ------------------------------------------------------------ byte paths
    def _require_backing(self) -> None:
        if self.backing is None:
            raise ValueError("byte fetch requested without a backing store")

    def _plan_ranges(self, file_path: PathT, offset: int, size: int,
                     out: ReadOutcome) -> List[Tuple[RangeRequest, bool]]:
        """Per-block exact sub-ranges covering the requested extent:
        ``[((block_path, start, length), hit), ...]`` in byte order.  The
        v2 ranged protocol means only the requested bytes move — no
        whole-block over-fetch on partial-extent reads."""
        bs = self.engine.cfg.block_size
        first = offset // bs
        # out.blocks carry populated block sizes (file tail may be short);
        # clamp the requested range to what the kernel actually served
        last_b = first + len(out.blocks) - 1
        end = min(offset + size, last_b * bs + out.blocks[-1].size)
        plan: List[Tuple[RangeRequest, bool]] = []
        for i, blk in enumerate(out.blocks):
            b = first + i
            start = max(offset, b * bs) - b * bs
            stop = min(end, b * bs + blk.size) - b * bs
            if stop > start:
                plan.append(((block_key(file_path, b), start, stop - start),
                             blk.hit))
        return plan

    def _fetch_hits(self, plans: List[List[Tuple[RangeRequest, bool]]],
                    fetched: Dict[RangeRequest, np.ndarray]) -> None:
        """Read the cache-hit ranges of every plan locally in **one**
        batched ``fetch_many`` (synthesized/served by the backing store —
        the repo carries no block payload store), deduped across plans
        and against already-demand-fetched ranges."""
        local: List[RangeRequest] = []
        for plan in plans:
            for r, hit in plan:
                if hit and r not in fetched:
                    fetched[r] = None  # type: ignore[assignment]  # dedup
                    local.append(r)
        if local:
            fetched.update(zip(local, self.executor.fetch_ranges(local)))

    def _assemble(self, plan: List[Tuple[RangeRequest, bool]],
                  fetched: Dict[RangeRequest, np.ndarray]) -> np.ndarray:
        """Stitch one extent together from the fetched range map."""
        chunks = [np.asarray(fetched[r], dtype=np.uint8) for r, _ in plan]
        if not chunks:
            return np.empty(0, dtype=np.uint8)
        return chunks[0] if len(chunks) == 1 else np.concatenate(chunks)

    # ------------------------------------------------------ kernel passthrough
    def complete_prefetch(self, path: PathT, size: int,
                          now: Optional[float] = None) -> bool:
        if now is None:
            now = self.clock()
        with self.guard.lock_for(path):
            return self.engine.complete_prefetch(path, size, now)

    def cancel_prefetch(self, path: PathT) -> None:
        with self.guard.lock_for(path):
            self.engine.cancel_prefetch(path)

    def tick(self, now: Optional[float] = None) -> None:
        if now is None:
            now = self.clock()
        self.guard.acquire_all()
        try:
            self.engine.tick(now)
        finally:
            self.guard.release_all()

    def pin(self, path: PathT) -> None:
        self.guard.acquire_all()
        try:
            self.engine.pin(path)
        finally:
            self.guard.release_all()

    def never_cache(self, path: PathT) -> None:
        self.guard.acquire_all()
        try:
            self.engine.never_cache(path)
        finally:
            self.guard.release_all()

    # ----------------------------------------------------------------- stats
    @property
    def meta(self):
        return self.engine.meta

    @property
    def cfg(self) -> CacheConfig:
        return self.engine.cfg

    @property
    def stats(self):
        return self.engine.stats

    def hit_ratio(self) -> float:
        return self.engine.hit_ratio()

    def store_capabilities(self):
        """Negotiated capabilities of the backing store (``None`` for a
        metadata-only client)."""
        if self.backing is None:
            return None
        caps = getattr(self.backing, "capabilities", None)
        if caps is None:
            from ..storage.api import StoreCapabilities
            return StoreCapabilities()
        return caps()

    def snapshot(self) -> dict:
        s = self.engine.snapshot()
        s["executor"] = self.executor.stats.snapshot()
        s["client"] = self.client_stats.snapshot()
        caps = self.store_capabilities()
        if caps is not None:
            s["store"] = {"capabilities": caps.snapshot()}
        if self.breaker is not None:
            s.setdefault("store", {})["breaker"] = self.breaker.snapshot()
        tiers = getattr(self.backing, "tier_stats", None)
        if callable(tiers):
            s.setdefault("store", {})["tiers"] = tiers()
        return s

    def fault_stats(self) -> dict:
        """Supervision observability of the underlying driver (shard
        states, restart budgets, kill/respawn events) plus this client's
        degraded-path counters.  In-process engines have no failure
        domains, so their driver section is empty."""
        fn = getattr(self.engine, "fault_stats", None)
        got = fn() if fn is not None else {"restarts": 0, "shards": {},
                                           "events": []}
        got["client"] = self.client_stats.snapshot()
        return got

    def shard_states(self) -> List[str]:
        fn = getattr(self.engine, "shard_states", None)
        if fn is not None:
            return fn()
        return [SHARD_UP] * getattr(self.engine, "n_shards", 1)

    def iter_workload_cmus(self):
        return self.engine.iter_workload_cmus()

    # ------------------------------------------------------------- lifecycle
    def set_executor(self, executor: PrefetchExecutor) -> None:
        """Swap the prefetch transport: the old executor is closed (its
        queued candidates cancelled on the kernel) and the new one is
        attached.  The cluster simulator uses this to re-route a client's
        prefetches onto its simulated link."""
        self.executor.close(cancel_pending=True)
        executor.attach(self.engine, self.backing, self.guard, self.clock,
                        self.retry)
        self.executor = executor

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Wait until every in-flight prefetch completed (ThreadedExecutor;
        inline executors are always drained)."""
        return self.executor.flush(timeout)

    def close(self, cancel_pending: bool = True) -> None:
        """Shut the executor down (cancelling queued candidates on the
        kernel), then — when this client constructed its engine
        (``open_cache``) — the engine itself.  In-process kernels carry
        no OS resources; the multi-process driver joins its workers and
        releases the shared-memory arena."""
        if self._closed:
            return
        self._closed = True
        self.executor.close(cancel_pending=cancel_pending)
        if self._own_engine:
            engine_close = getattr(self.engine, "close", None)
            if engine_close is not None:
                engine_close()

    def __enter__(self) -> "CacheClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


_EXECUTORS = ("sim", "threaded", "none", "process")


def open_cache(store, capacity: Optional[int] = None, *,
               cfg: Optional[CacheConfig] = None,
               options: Optional[EngineOptions] = None,
               n_shards: int = 1,
               driver: str = "thread",
               n_procs: Optional[int] = None,
               arena_bytes: Optional[int] = None,
               executor: Optional[Union[str, PrefetchExecutor]] = None,
               backing=None,
               clock: Optional[Callable[[], float]] = None,
               fetch_bytes: bool = False,
               retry=None,
               queue_depth: int = 4096,
               max_fetch_bytes: int = 4096,
               degraded: bool = True,
               breaker=None,
               supervise: bool = True,
               restart_budget: int = 3,
               restart_window_s: float = 60.0,
               heartbeat_s: Optional[float] = None,
               rpc_timeout_s: float = 30.0) -> CacheClient:
    """The one constructor path: store (instance or URI) + capacity →
    CacheClient.

    ``store`` is either a store object or a URI for the scheme registry
    (``"sim://default"``, ``"file:///data/dir"``, ``"mem://"``,
    ``"faulty+sim://default?fail_rate=0.1&seed=7"`` — see
    ``storage.api.open_store``).  It doubles as the kernel's
    ``StoreMeta`` and (unless ``backing`` overrides it) the client's
    backing store; legacy one-method ``fetch_block`` stores are adapted
    automatically.

    A ``cache://`` URI (or ``DaemonAddress``) is special: it names a
    running :class:`~repro.daemon.CacheDaemon`, so ``open_cache``
    returns a connected ``RemoteCacheClient`` session instead of
    building an engine — ``capacity`` must be omitted (the daemon owns
    engine configuration) and only ``fetch_bytes`` plus the URI's query
    params apply.

    ``driver`` selects where the shard kernels run:

    * ``"thread"`` (default) — in this process (``make_engine``:
      the plain ``IGTCache`` at ``n_shards=1``, the ``ShardedIGTCache``
      facade otherwise);
    * ``"process"`` — one worker process per shard
      (``core.procdriver.ProcessShardedCache``), ``n_procs`` of them
      (defaults to ``n_shards`` when that is > 1, else 2), with fetched
      bytes crossing through a shared-memory arena of ``arena_bytes``.

    ``executor`` picks the prefetch transport: ``"sim"`` (deterministic
    inline, virtual-clock callers), ``"threaded"`` (per-shard background
    workers, wall-clock callers), ``"none"`` (read-only: candidates
    cancelled), ``"process"`` (worker-resident fetch+complete — requires
    ``driver="process"``), or a pre-built :class:`PrefetchExecutor`
    instance.  When omitted it follows the driver: ``"sim"`` in-process,
    ``"process"`` for the process driver.  ``retry`` is the
    ``storage.api.RetryPolicy`` guarding every byte fetch.

    Fault tolerance (see docs/RELIABILITY.md): ``degraded`` keeps reads
    flowing around a dead shard (direct store fetches, counted in
    ``ClientStats``); ``breaker`` is an optional
    ``storage.api.CircuitBreaker`` guarding client-side fetches.  The
    remaining knobs configure the process driver's supervisor and are
    ignored by ``driver="thread"`` (in-process shards share this
    process's fate — there is nothing to supervise): ``supervise``
    (respawn dead shard workers), ``restart_budget`` restarts per
    ``restart_window_s`` seconds before a shard goes permanently down,
    ``heartbeat_s`` (liveness deadline for hung-worker detection, off by
    default), and ``rpc_timeout_s`` (per-RPC reply deadline; a breach
    kills and respawns the worker instead of hanging the caller).
    """
    if isinstance(store, str):
        from ..storage.api import open_store
        store = open_store(store)
    if getattr(store, "is_cache_address", False):
        # cache://<sock-or-host:port> — a running CacheDaemon endpoint:
        # the daemon already owns the engine (capacity, shards, driver,
        # executor), so the answer is a thin connected session, not a
        # locally constructed stack.  URI query params (?fetch_bytes=
        # true&label=trainer0) merge under explicit kwargs.
        from ..daemon.client import RemoteCacheClient
        if capacity is not None:
            raise ValueError(
                "capacity is owned by the daemon for cache:// stores — "
                "configure it where the CacheDaemon is constructed")
        params = dict(store.params)
        params.setdefault("fetch_bytes", fetch_bytes)
        allowed = ("fetch_bytes", "label", "heartbeat", "shm",
                   "connect_timeout", "reconnect", "degraded",
                   "max_backoff_s", "rpc_timeout_s")
        kw = {k: v for k, v in params.items() if k in allowed}
        if backing is not None:
            # degraded reads while the daemon is away need a local byte
            # path; a backing store (object or URI) provides it
            if isinstance(backing, str):
                from ..storage.api import open_store
                backing = open_store(backing)
            kw["backing"] = backing
        return RemoteCacheClient(store, **kw)
    if capacity is None:
        raise TypeError("open_cache() missing required argument: "
                        "'capacity' (only cache:// stores omit it)")
    if driver not in ("thread", "process"):
        raise ValueError(f"unknown driver {driver!r}; expected 'thread' "
                         f"or 'process'")
    if executor is None:
        executor = "process" if driver == "process" else "sim"
    if isinstance(executor, str) and executor not in _EXECUTORS:
        # validate BEFORE constructing the engine: a process-backed
        # engine spawns workers that must not leak over a typo
        raise ValueError(
            f"unknown executor {executor!r}; expected one of "
            f"{sorted(_EXECUTORS)} or a PrefetchExecutor instance")
    if driver == "process":
        from .procdriver import DEFAULT_ARENA_BYTES, ProcessShardedCache
        if n_procs is None:
            n_procs = n_shards if n_shards > 1 else 2
        engine: Engine = ProcessShardedCache(
            store, capacity, cfg=cfg, options=options, n_procs=n_procs,
            arena_bytes=(DEFAULT_ARENA_BYTES if arena_bytes is None
                         else arena_bytes),
            backing=backing,     # workers serve demand misses from it
            retry=retry,
            supervise=supervise, restart_budget=restart_budget,
            restart_window_s=restart_window_s, heartbeat_s=heartbeat_s,
            rpc_timeout_s=rpc_timeout_s)
    else:
        if n_procs is not None:
            raise ValueError("n_procs only applies to driver='process'")
        engine = make_engine(store, capacity, cfg=cfg, options=options,
                             n_shards=n_shards)
    if backing is None:
        backing = store          # normalized (or rejected) by CacheClient
    if isinstance(executor, str):
        if executor == "threaded":
            executor = ThreadedExecutor(queue_depth=queue_depth,
                                        max_fetch_bytes=max_fetch_bytes)
        elif executor == "process":
            from .procdriver import ProcessExecutor
            executor = ProcessExecutor(queue_depth=queue_depth,
                                       max_fetch_bytes=max_fetch_bytes)
        elif executor == "sim":
            executor = SimExecutor()
        else:
            executor = NullExecutor()
    try:
        client = CacheClient(engine, backing=backing, executor=executor,
                             clock=clock, fetch_bytes=fetch_bytes,
                             retry=retry, degraded=degraded,
                             breaker=breaker)
    except BaseException:
        engine_close = getattr(engine, "close", None)
        if engine_close is not None:     # never leak worker processes
            engine_close()
        raise
    client._own_engine = True
    return client

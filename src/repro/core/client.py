"""The unified two-layer cache API: kernel engine + ``CacheClient``.

The paper's engine is a pure observe→recognize→adapt state machine; the
I/O contract around it — who fetches missed bytes, who runs prefetch
candidates, who calls ``complete_prefetch`` when background bytes land —
was re-implemented by every consumer (the cluster simulator's event loop,
the token pipeline's ad-hoc worker thread, raw loops in the examples).
This module absorbs that contract behind one client interface (IGTCache
§2's "no code intrusion" claim; Hoard arXiv:1812.00669 draws the same
line between cache kernel and client library).

Two layers:

**Kernel layer** — the engine itself (``IGTCache`` / ``ShardedIGTCache``),
a deterministic single-threaded state machine with the documented surface

    read / read_batch / complete_prefetch / cancel_prefetch / tick /
    pin / never_cache / stats / hit_ratio / snapshot / iter_workload_cmus

The kernel never does I/O and never owns time: every call takes ``now``.
This is the property-test surface (tests/test_equivalence.py) and stays
available for callers that need full control (the discrete-event
simulator owns bandwidth, so it drives the kernel through the client with
a link-backed executor; see ``sim.cluster.LinkExecutor``).

**Client layer** — ``CacheClient`` wraps a kernel with

  * a pluggable :class:`BackingStore` (``storage.RemoteStore`` satisfies
    it) that supplies actual bytes, and
  * a :class:`PrefetchExecutor` that runs the kernel's prefetch
    candidates: the deterministic inline :class:`SimExecutor` (virtual
    clock; bitwise-equivalent to the caller-driven loop) or the
    :class:`ThreadedExecutor` (one worker per kernel shard — shards share
    no read-path state — bounded queues, demand-miss > prefetch priority,
    in-queue dedup, and cancellation that calls ``cancel_prefetch`` on
    overflow/shutdown instead of silently dropping candidates).

``open_cache(store, capacity, ...) -> CacheClient`` is the one
constructor path all consumers share; every future scaling lever
(multi-process shards, real object stores) plugs in behind these two
protocols.  See docs/API.md for the full contract.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import (Callable, Deque, Dict, List, Optional, Sequence, Set,
                    Tuple, Union)

import numpy as np

from .cache import block_key
from .igtcache import EngineOptions, ReadOutcome
from .sharded import Engine, ShardedIGTCache, make_engine
from .types import CacheConfig, PathT

__all__ = [
    "BackingStore", "CacheClient", "ExecutorStats", "KernelGuard",
    "NullExecutor", "PrefetchExecutor", "ReadResult", "SimExecutor",
    "ThreadedExecutor", "open_cache",
]


class BackingStore:
    """Protocol for the byte source behind the cache (duck-typed; the
    simulated ``storage.RemoteStore`` satisfies it as-is).

    ``fetch_block(block_path, size) -> np.ndarray[uint8]`` returns the
    first ``size`` bytes of the 4 MB block at ``block_path`` (a file path
    tuple ending in ``"#<n>"``).  Adapters over real object stores (S3,
    GCS) implement exactly this one method.
    """

    def fetch_block(self, block_path: PathT,
                    size: int) -> np.ndarray:  # pragma: no cover - protocol
        raise NotImplementedError


@dataclass
class ExecutorStats:
    """Candidate accounting for one executor (lost-candidate audit trail:
    ``submitted == completed + cancelled + deduped + in_flight``)."""

    submitted: int = 0        # candidates handed to submit()
    completed: int = 0        # complete_prefetch delivered to the kernel
    cancelled: int = 0        # cancel_prefetch on overflow / shutdown
    deduped: int = 0          # dropped: same block already queued/in flight
    demand_fetches: int = 0   # priority demand-miss fetches served

    def snapshot(self) -> dict:
        return {"submitted": self.submitted, "completed": self.completed,
                "cancelled": self.cancelled, "deduped": self.deduped,
                "demand_fetches": self.demand_fetches}


class KernelGuard:
    """Per-shard mutual exclusion for the kernel.

    The kernel is a single-threaded state machine; a ``ShardedIGTCache``
    is N independent ones (shards share no read-path state, so per-shard
    locks give shard-parallel readers/completers).  Cross-shard
    operations (``tick`` with the global rebalancer, ``pin``) take all
    locks in index order.  For a plain ``IGTCache`` there is one lock.
    """

    def __init__(self, engine: Engine) -> None:
        self.engine = engine
        n = engine.n_shards if isinstance(engine, ShardedIGTCache) else 1
        self._locks = [threading.Lock() for _ in range(n)]
        self._sharded = isinstance(engine, ShardedIGTCache)

    @property
    def n_shards(self) -> int:
        return len(self._locks)

    def shard_id(self, path: PathT) -> int:
        if not self._sharded:
            return 0
        return self.engine.shard_id(path)

    def lock_for(self, path: PathT) -> threading.Lock:
        return self._locks[self.shard_id(path)]

    def lock_shard(self, sid: int) -> threading.Lock:
        return self._locks[sid]

    def acquire_all(self) -> None:
        for lk in self._locks:          # fixed order: no deadlock
            lk.acquire()

    def release_all(self) -> None:
        for lk in reversed(self._locks):
            lk.release()


class PrefetchExecutor:
    """Protocol + shared plumbing for prefetch candidate execution.

    Lifecycle: constructed unattached (configuration only), then
    ``attach``-ed exactly once by the :class:`CacheClient` that owns it.
    ``submit`` receives the candidates of one read at timestamp ``now``;
    the executor must eventually either ``complete_prefetch`` or
    ``cancel_prefetch`` every candidate on the kernel — never drop one
    silently (the kernel tracks pending candidates for dedup, so a
    dropped candidate blocks that block's re-issue forever).
    """

    def __init__(self) -> None:
        self.stats = ExecutorStats()
        self.engine: Optional[Engine] = None
        self.backing: Optional[BackingStore] = None
        self.guard: Optional[KernelGuard] = None
        self.clock: Callable[[], float] = time.monotonic

    def attach(self, engine: Engine, backing: Optional[BackingStore],
               guard: KernelGuard, clock: Callable[[], float]) -> None:
        if self.engine is not None and self.engine is not engine:
            raise RuntimeError("executor is already attached to a kernel")
        self.engine = engine
        self.backing = backing
        self.guard = guard
        self.clock = clock

    # -- candidate path -----------------------------------------------------
    def submit(self, candidates: Sequence[Tuple[PathT, int]],
               now: float) -> None:  # pragma: no cover - protocol
        raise NotImplementedError

    # -- demand path (priority over prefetch) -------------------------------
    def fetch_demand(self, requests: Sequence[Tuple[PathT, int]]
                     ) -> List[np.ndarray]:
        """Fetch demand-missed blocks; must preempt queued prefetches."""
        self.stats.demand_fetches += len(requests)
        assert self.backing is not None, "demand fetch needs a BackingStore"
        return [self.backing.fetch_block(p, s) for p, s in requests]

    # -- lifecycle ----------------------------------------------------------
    def flush(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted candidate completed or cancelled."""
        return True

    def close(self, cancel_pending: bool = True) -> None:
        pass


class SimExecutor(PrefetchExecutor):
    """Deterministic inline executor for virtual-clock callers.

    ``submit`` completes every candidate synchronously at the read's own
    ``now`` — exactly the caller-driven loop the discrete-event tests and
    the non-threaded pipeline ran by hand, so a client with a SimExecutor
    is bitwise-equivalent to that loop (pinned in
    tests/test_equivalence.py).  ``max_fetch_bytes=0`` (default) moves no
    bytes: pure-simulation callers only track sizes and latencies.
    """

    def __init__(self, max_fetch_bytes: int = 0) -> None:
        super().__init__()
        self.max_fetch_bytes = max_fetch_bytes

    def submit(self, candidates: Sequence[Tuple[PathT, int]],
               now: float) -> None:
        if not candidates:
            return
        self.stats.submitted += len(candidates)
        eng = self.engine
        for path, size in candidates:
            if self.backing is not None and self.max_fetch_bytes > 0:
                self.backing.fetch_block(path, min(size,
                                                   self.max_fetch_bytes))
            eng.complete_prefetch(path, size, now)
            self.stats.completed += 1


class NullExecutor(PrefetchExecutor):
    """Read-only client: every candidate is cancelled immediately (the
    kernel's pending-table stays clean; nothing is fetched)."""

    def submit(self, candidates: Sequence[Tuple[PathT, int]],
               now: float) -> None:
        if not candidates:
            return
        self.stats.submitted += len(candidates)
        for path, _size in candidates:
            self.engine.cancel_prefetch(path)
            self.stats.cancelled += 1


class _DemandItem:
    __slots__ = ("path", "size", "data", "error", "event")

    def __init__(self, path: PathT, size: int) -> None:
        self.path = path
        self.size = size
        self.data: Optional[np.ndarray] = None
        self.error: Optional[BaseException] = None
        self.event = threading.Event()


class _ShardQueue:
    """Two-class bounded queue for one shard worker.

    Demand items (missed bytes a reader is blocked on) always pop before
    background prefetch candidates and are never rejected; the background
    class is bounded by ``depth`` and rejects on overflow (the caller
    cancels the candidate on the kernel).  ``keys`` is the in-queue /
    in-flight dedup set for background candidates.
    """

    def __init__(self, depth: int) -> None:
        self.depth = depth
        self.cv = threading.Condition()
        self.demand: Deque[_DemandItem] = deque()
        self.background: Deque[Tuple[PathT, int, str]] = deque()
        self.keys: Set[str] = set()          # queued + in-flight candidates
        self.outstanding = 0                 # background items not yet done
        self.closed = False

    def put_demand(self, item: _DemandItem) -> bool:
        with self.cv:
            if self.closed:
                return False
            self.demand.append(item)
            self.cv.notify()
            return True

    def offer_background(self, path: PathT, size: int,
                         key: str) -> str:
        """Returns 'queued' | 'dup' | 'full' | 'closed'."""
        with self.cv:
            if self.closed:
                return "closed"
            if key in self.keys:
                return "dup"
            if len(self.background) >= self.depth:
                return "full"
            self.keys.add(key)
            self.background.append((path, size, key))
            self.outstanding += 1
            self.cv.notify()
            return "queued"

    def get(self, timeout: float):
        with self.cv:
            if not self.demand and not self.background:
                self.cv.wait(timeout)
            if self.demand:
                return self.demand.popleft()
            if self.background:
                return self.background.popleft()
            return None

    def task_done(self, key: str) -> None:
        with self.cv:
            self.keys.discard(key)
            self.outstanding -= 1
            self.cv.notify_all()

    def drain_background(self) -> List[Tuple[PathT, int, str]]:
        with self.cv:
            items = list(self.background)
            self.background.clear()
            for _, _, key in items:
                self.keys.discard(key)
                self.outstanding -= 1
            self.cv.notify_all()
            return items

    def wait_idle(self, timeout: Optional[float]) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self.cv:
            while self.outstanding > 0 or self.demand:
                rem = None if deadline is None else deadline - time.monotonic()
                if rem is not None and rem <= 0:
                    return False
                self.cv.wait(rem if rem is not None else 0.1)
        return True


class ThreadedExecutor(PrefetchExecutor):
    """Per-shard background prefetch workers.

    One daemon worker per kernel shard (``IGTCache`` counts as one
    shard); a candidate is routed to its block's shard worker, so
    completions only ever contend with reads of the same shard — the
    multi-worker shard driver from the ROADMAP.  Per-shard queues are
    bounded; an overflowing candidate is *cancelled on the kernel*
    (``cancel_prefetch``) so the pending-table never leaks, and shutdown
    cancels everything still queued.  Demand-miss fetches jump every
    queue (strict priority) and are never rejected.
    """

    def __init__(self, queue_depth: int = 4096,
                 max_fetch_bytes: int = 4096,
                 poll_s: float = 0.05) -> None:
        super().__init__()
        self.queue_depth = queue_depth
        self.max_fetch_bytes = max_fetch_bytes
        self.poll_s = poll_s
        self._queues: List[_ShardQueue] = []
        self._workers: List[threading.Thread] = []
        self._stats_lock = threading.Lock()
        self._stop = threading.Event()
        self._started = False

    # -- lifecycle ----------------------------------------------------------
    def attach(self, engine: Engine, backing: Optional[BackingStore],
               guard: KernelGuard, clock: Callable[[], float]) -> None:
        super().attach(engine, backing, guard, clock)
        if self._started:
            return
        self._started = True
        for sid in range(guard.n_shards):
            q = _ShardQueue(self.queue_depth)
            w = threading.Thread(target=self._run, args=(sid, q),
                                 name=f"igt-prefetch-{sid}", daemon=True)
            self._queues.append(q)
            self._workers.append(w)
            w.start()

    def close(self, cancel_pending: bool = True) -> None:
        if not self._started or self._stop.is_set():
            return
        if not cancel_pending:
            self.flush()
        for q in self._queues:          # late offers now reject as 'closed'
            with q.cv:
                q.closed = True
        self._cancel_queued()
        self._stop.set()
        for w in self._workers:
            w.join(timeout=2.0)
        # workers are down: anything that slipped between drain and join is
        # cancelled too — a candidate must never be dropped silently —
        # and stranded demand waiters are released with an error
        self._cancel_queued()
        for q in self._queues:
            with q.cv:
                while q.demand:
                    item = q.demand.popleft()
                    item.error = RuntimeError(
                        "ThreadedExecutor closed with the fetch in queue")
                    item.event.set()

    def _cancel_queued(self) -> None:
        for sid, q in enumerate(self._queues):
            for path, _size, _key in q.drain_background():
                with self.guard.lock_shard(sid):
                    self.engine.cancel_prefetch(path)
                with self._stats_lock:
                    self.stats.cancelled += 1

    def flush(self, timeout: Optional[float] = None) -> bool:
        return all(q.wait_idle(timeout) for q in self._queues)

    # -- candidate path -----------------------------------------------------
    def submit(self, candidates: Sequence[Tuple[PathT, int]],
               now: float) -> None:
        if not candidates:
            return
        guard = self.guard
        with self._stats_lock:
            self.stats.submitted += len(candidates)
        for path, size in candidates:
            sid = guard.shard_id(path)
            got = self._queues[sid].offer_background(path, size,
                                                     block_key(path))
            if got == "queued":
                continue
            if got == "dup":
                # same block already queued/in flight: this duplicate
                # candidate will never get its own completion — release it
                with guard.lock_shard(sid):
                    self.engine.cancel_prefetch(path)
                with self._stats_lock:
                    self.stats.deduped += 1
            else:  # full / closed → cancel instead of silently dropping
                with guard.lock_shard(sid):
                    self.engine.cancel_prefetch(path)
                with self._stats_lock:
                    self.stats.cancelled += 1

    # -- demand path --------------------------------------------------------
    def fetch_demand(self, requests: Sequence[Tuple[PathT, int]]
                     ) -> List[np.ndarray]:
        """Route each demand miss to its shard worker (priority class) and
        block until all land — misses of one batch fetch shard-parallel."""
        assert self.backing is not None, "demand fetch needs a BackingStore"
        with self._stats_lock:
            self.stats.demand_fetches += len(requests)
        items = []
        for path, size in requests:
            item = _DemandItem(path, size)
            items.append(item)
            if not self._queues[self.guard.shard_id(path)].put_demand(item):
                item.error = RuntimeError(
                    "demand fetch on a closed ThreadedExecutor")
                item.event.set()
        for item in items:
            item.event.wait()
        for item in items:
            if item.error is not None:  # re-raise in the reader's thread
                raise item.error
        return [item.data for item in items]

    # -- worker loop --------------------------------------------------------
    def _run(self, sid: int, q: _ShardQueue) -> None:
        guard = self.guard
        while not self._stop.is_set():
            got = q.get(self.poll_s)
            if got is None:
                continue
            if isinstance(got, _DemandItem):
                # a failing backing store (real S3/GCS adapters will fail)
                # must not kill the shard worker or strand the blocked
                # reader: hand the error back through the item
                try:
                    got.data = self.backing.fetch_block(got.path, got.size)
                except BaseException as e:
                    got.error = e
                finally:
                    got.event.set()
                    with q.cv:
                        q.cv.notify_all()
                continue
            path, size, key = got
            try:
                try:
                    if self.backing is not None and self.max_fetch_bytes > 0:
                        # the actual byte movement (capped: content is what
                        # a real store would stream; the kernel only needs
                        # sizes)
                        self.backing.fetch_block(
                            path, min(size, self.max_fetch_bytes))
                    with guard.lock_shard(sid):
                        self.engine.complete_prefetch(path, size,
                                                      self.clock())
                    with self._stats_lock:
                        self.stats.completed += 1
                except Exception:
                    # failed fetch → the candidate will never complete:
                    # release it on the kernel, keep the worker alive
                    with guard.lock_shard(sid):
                        self.engine.cancel_prefetch(path)
                    with self._stats_lock:
                        self.stats.cancelled += 1
            finally:
                q.task_done(key)


class ReadResult:
    """One client read: the kernel's per-block outcome plus, when the
    client fetched through its BackingStore, the requested bytes."""

    __slots__ = ("outcome", "data")

    def __init__(self, outcome: ReadOutcome,
                 data: Optional[np.ndarray] = None) -> None:
        self.outcome = outcome
        self.data = data

    @property
    def blocks(self):
        return self.outcome.blocks

    @property
    def cached_bytes(self) -> int:
        return self.outcome.cached_bytes

    @property
    def remote_bytes(self) -> int:
        return self.outcome.remote_bytes


class CacheClient:
    """The caller layer: reads + prefetch execution over one kernel.

    ``read``/``read_batch`` serve through the kernel under the shard
    guard, hand the kernel's prefetch candidates to the executor, and —
    when asked for bytes — fetch hits inline and misses through the
    executor's priority demand path.  All kernel introspection
    (``stats``, ``snapshot``, ``iter_workload_cmus``) passes through.

    Time: pass ``now`` explicitly (virtual-clock callers) or omit it to
    use the client's ``clock`` (default ``time.monotonic``).
    """

    def __init__(self, engine: Engine, *,
                 backing: Optional[BackingStore] = None,
                 executor: Optional[PrefetchExecutor] = None,
                 clock: Optional[Callable[[], float]] = None,
                 fetch_bytes: bool = False) -> None:
        self.engine = engine
        self.backing = backing
        self.clock = clock or time.monotonic
        self.guard = KernelGuard(engine)
        self.executor = executor if executor is not None else SimExecutor()
        self.executor.attach(engine, backing, self.guard, self.clock)
        self.fetch_bytes = fetch_bytes
        if fetch_bytes and backing is None:
            raise ValueError("fetch_bytes=True needs a BackingStore")
        self._closed = False

    # ------------------------------------------------------------------ read
    def read(self, file_path: PathT, offset: int, size: int,
             now: Optional[float] = None, *,
             fetch: Optional[bool] = None) -> ReadResult:
        """Serve one extent: kernel read → executor-dispatched prefetch →
        (optionally) bytes for the requested range."""
        if now is None:
            now = self.clock()
        with self.guard.lock_for(file_path):
            out = self.engine.read(file_path, offset, size, now)
        if out.prefetches:
            self.executor.submit(out.prefetches, now)
        return self._finish(file_path, offset, size, out, fetch)

    def read_batch(self, requests: Sequence[Tuple[PathT, int, int]],
                   now: Optional[float] = None, *,
                   fetch: Optional[bool] = None) -> List[ReadResult]:
        """One kernel ``read_batch`` (tick amortized per batch), prefetch
        dispatch per outcome, demand bytes fetched shard-parallel."""
        if now is None:
            now = self.clock()
        self.guard.acquire_all()
        try:
            outs = self.engine.read_batch(requests, now)
        finally:
            self.guard.release_all()
        for out in outs:
            if out.prefetches:
                self.executor.submit(out.prefetches, now)
        return [self._finish(fp, off, sz, out, fetch)
                for (fp, off, sz), out in zip(requests, outs)]

    def _finish(self, file_path: PathT, offset: int, size: int,
                out: ReadOutcome, fetch: Optional[bool]) -> ReadResult:
        want = self.fetch_bytes if fetch is None else fetch
        if not want or not out.blocks:
            return ReadResult(out)
        if self.backing is None:
            raise ValueError("byte fetch requested without a BackingStore")
        return ReadResult(out, self._fetch_range(file_path, offset, size,
                                                 out))

    def _fetch_range(self, file_path: PathT, offset: int, size: int,
                     out: ReadOutcome) -> np.ndarray:
        """Assemble the requested byte range: cache hits read locally
        (synthesized by the backing store — the repo carries no block
        payload store), demand misses go through the executor's priority
        demand path (shard-parallel under the ThreadedExecutor)."""
        bs = self.engine.cfg.block_size
        first = offset // bs
        # out.blocks carry populated block sizes (file tail may be short);
        # clamp the requested range to what the kernel actually served
        last_b = first + len(out.blocks) - 1
        end = min(offset + size, last_b * bs + out.blocks[-1].size)
        pieces: List[Tuple[int, int, int]] = []   # (block, start, stop)
        demand: List[Tuple[PathT, int]] = []
        for i, blk in enumerate(out.blocks):
            b = first + i
            start = max(offset, b * bs) - b * bs
            stop = min(end, b * bs + blk.size) - b * bs
            pieces.append((b, start, stop))
            if not blk.hit:
                demand.append((file_path + (f"#{b}",), stop))
        fetched: Dict[PathT, np.ndarray] = {}
        if demand:
            for (bp, _sz), data in zip(demand,
                                       self.executor.fetch_demand(demand)):
                fetched[bp] = data
        chunks: List[np.ndarray] = []
        for b, start, stop in pieces:
            bp = file_path + (f"#{b}",)
            data = fetched.get(bp)
            if data is None:
                data = self.backing.fetch_block(bp, stop)
            chunks.append(np.asarray(data[start:stop], dtype=np.uint8))
        return chunks[0] if len(chunks) == 1 else np.concatenate(chunks)

    # ------------------------------------------------------ kernel passthrough
    def complete_prefetch(self, path: PathT, size: int,
                          now: Optional[float] = None) -> bool:
        if now is None:
            now = self.clock()
        with self.guard.lock_for(path):
            return self.engine.complete_prefetch(path, size, now)

    def cancel_prefetch(self, path: PathT) -> None:
        with self.guard.lock_for(path):
            self.engine.cancel_prefetch(path)

    def tick(self, now: Optional[float] = None) -> None:
        if now is None:
            now = self.clock()
        self.guard.acquire_all()
        try:
            self.engine.tick(now)
        finally:
            self.guard.release_all()

    def pin(self, path: PathT) -> None:
        self.guard.acquire_all()
        try:
            self.engine.pin(path)
        finally:
            self.guard.release_all()

    def never_cache(self, path: PathT) -> None:
        self.guard.acquire_all()
        try:
            self.engine.never_cache(path)
        finally:
            self.guard.release_all()

    # ----------------------------------------------------------------- stats
    @property
    def meta(self):
        return self.engine.meta

    @property
    def cfg(self) -> CacheConfig:
        return self.engine.cfg

    @property
    def stats(self):
        return self.engine.stats

    def hit_ratio(self) -> float:
        return self.engine.hit_ratio()

    def snapshot(self) -> dict:
        s = self.engine.snapshot()
        s["executor"] = self.executor.stats.snapshot()
        return s

    def iter_workload_cmus(self):
        return self.engine.iter_workload_cmus()

    # ------------------------------------------------------------- lifecycle
    def set_executor(self, executor: PrefetchExecutor) -> None:
        """Swap the prefetch transport: the old executor is closed (its
        queued candidates cancelled on the kernel) and the new one is
        attached.  The cluster simulator uses this to re-route a client's
        prefetches onto its simulated link."""
        self.executor.close(cancel_pending=True)
        executor.attach(self.engine, self.backing, self.guard, self.clock)
        self.executor = executor

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Wait until every in-flight prefetch completed (ThreadedExecutor;
        inline executors are always drained)."""
        return self.executor.flush(timeout)

    def close(self, cancel_pending: bool = True) -> None:
        """Shut the executor down (cancelling queued candidates on the
        kernel).  The kernel itself carries no OS resources to release."""
        if self._closed:
            return
        self._closed = True
        self.executor.close(cancel_pending=cancel_pending)

    def __enter__(self) -> "CacheClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


_EXECUTORS = {
    "sim": SimExecutor,
    "threaded": ThreadedExecutor,
    "none": NullExecutor,
}


def open_cache(store, capacity: int, *,
               cfg: Optional[CacheConfig] = None,
               options: Optional[EngineOptions] = None,
               n_shards: int = 1,
               executor: Union[str, PrefetchExecutor] = "sim",
               backing: Optional[BackingStore] = None,
               clock: Optional[Callable[[], float]] = None,
               fetch_bytes: bool = False,
               queue_depth: int = 4096,
               max_fetch_bytes: int = 4096) -> CacheClient:
    """The one constructor path: metadata store + capacity → CacheClient.

    ``store`` doubles as the kernel's ``StoreMeta`` and (unless
    ``backing`` overrides it) the client's ``BackingStore`` — the
    simulated ``RemoteStore`` satisfies both protocols.  ``executor``
    picks the prefetch transport: ``"sim"`` (deterministic inline,
    virtual-clock callers), ``"threaded"`` (per-shard background workers,
    wall-clock callers), ``"none"`` (read-only: candidates cancelled), or
    a pre-built :class:`PrefetchExecutor` instance.
    """
    engine = make_engine(store, capacity, cfg=cfg, options=options,
                         n_shards=n_shards)
    if backing is None and hasattr(store, "fetch_block"):
        backing = store
    if isinstance(executor, str):
        try:
            kind = _EXECUTORS[executor]
        except KeyError:
            raise ValueError(
                f"unknown executor {executor!r}; expected one of "
                f"{sorted(_EXECUTORS)} or a PrefetchExecutor instance")
        if kind is ThreadedExecutor:
            executor = ThreadedExecutor(queue_depth=queue_depth,
                                        max_fetch_bytes=max_fetch_bytes)
        elif kind is SimExecutor:
            executor = SimExecutor()
        else:
            executor = NullExecutor()
    return CacheClient(engine, backing=backing, executor=executor,
                       clock=clock, fetch_bytes=fetch_bytes)

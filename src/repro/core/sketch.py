"""Compact frequency sketches for cross-shard demand tracking (PR 7).

The cross-shard allocation round needs each shard's *demand heat* — which
blocks keep re-missing after eviction, and how big each stream's unmet
working set is — but shipping exact per-block counters grows with the
block population (millions of distinct blocks at production scale).  This
module provides the two classic bounded-error summaries:

* :class:`CountMinSketch` — conservative-update CMS with seeded hash rows,
  NumPy-vectorized batch folding.  Point queries never under-count, and
  over-count by at most ``2/width`` of the total mass per row with
  probability ``1 - 2^-depth`` (the standard CM bound; conservative update
  only tightens it).  ``merge`` is element-wise addition, which preserves
  the over-estimate guarantee for the combined stream.
* :class:`SpaceSaving` — top-k heavy hitters with per-entry error bounds.
  Any key whose true count exceeds ``total/k`` is guaranteed present, and
  every reported count over-estimates truth by at most the recorded
  ``err``.

Both serialize to bounded O(KB) payloads (zlib over the mostly-zero CMS
table; length-prefixed entries for the top-k) so a shard's whole demand
summary fits in a few wire KB regardless of block population —
``ShardDemandTracker`` ships them over the rebalance RPC and
``GlobalRebalancer`` merges them into a cluster heat view.

:class:`DemandSketch` is the per-shard composite the cache feeds on ghost
hits (re-misses of recently evicted blocks — exactly the misses that one
more byte of quota could have saved).  The hot path is a plain list
append; hashing and sketch updates amortize over vectorized folds.
"""
from __future__ import annotations

import struct
import zlib
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from .types import CacheConfig

_CMS_MAGIC = b"CMS1"
_SS_MAGIC = b"SSK1"

# 64-bit mixing constants for the row hash family (splitmix64 increments).
_MIX = np.uint64(0x9E3779B97F4A7C15)


def stable_hash64(key: str) -> int:
    """Process-stable 64-bit hash of a string key.

    Built from two CRC-32 passes (forward and salted) — cheap, stable
    across processes (unlike the salted builtin ``hash``), and good
    enough spread once mixed through the per-row affine family.
    """
    b = key.encode("utf-8")
    lo = zlib.crc32(b)
    hi = zlib.crc32(b, 0x9E3779B9)
    return (hi << 32) | lo


def _hash_batch(keys) -> np.ndarray:
    """Vectorized :func:`stable_hash64` over a sequence of keys.

    Bound locals + a tight generator: this runs on every fold, so the
    Python-level per-key overhead matters (see the sketch micro-bench in
    ``benchmarks/allocation_micro.py``).
    """
    crc = zlib.crc32

    def gen():
        for k in keys:
            b = k.encode("utf-8")
            yield (crc(b, 0x9E3779B9) << 32) | crc(b)
    n = len(keys) if hasattr(keys, "__len__") else -1
    return np.fromiter(gen(), dtype=np.uint64, count=n)


def _mix64(h: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer, vectorized (uint64 wrap-around intended)."""
    with np.errstate(over="ignore"):
        z = h * _MIX
        z ^= z >> np.uint64(30)
        z *= np.uint64(0xBF58476D1CE4E5B9)
        z ^= z >> np.uint64(27)
        z *= np.uint64(0x94D049BB133111EB)
        z ^= z >> np.uint64(31)
    return z


class CountMinSketch:
    """Conservative-update Count-Min sketch over string keys.

    ``depth`` seeded hash rows of ``width`` uint64 counters.  Updates are
    *conservative*: only the cells that currently hold the key's minimum
    estimate are raised, which keeps the classic over-estimate guarantee
    while shrinking collision inflation.  Batched updates
    (:meth:`update_hashed`) read all row minima first and raise cells
    with ``np.maximum.at`` — order-independent, still never
    under-counting.
    """

    def __init__(self, width: int = 512, depth: int = 3,
                 seed: int = 0) -> None:
        if width < 8 or depth < 1:
            raise ValueError(f"bad CMS geometry {width}x{depth}")
        self.width = int(width)
        self.depth = int(depth)
        self.seed = int(seed)
        self.table = np.zeros((depth, width), dtype=np.uint64)
        self.total = 0          # mass added (sum of counts)
        rng = np.random.default_rng(seed)
        # odd multipliers + offsets: one affine 64-bit mix per row
        self._a = (rng.integers(1, 2**63, size=depth, dtype=np.uint64)
                   | np.uint64(1))
        self._b = rng.integers(0, 2**63, size=depth, dtype=np.uint64)
        self._rows = np.arange(depth)

    # ------------------------------------------------------------- hashing
    def _indices(self, hashes: np.ndarray) -> np.ndarray:
        """(depth, n) column indices for n pre-hashed keys."""
        with np.errstate(over="ignore"):
            mixed = _mix64(hashes[None, :] * self._a[:, None]
                           + self._b[:, None])
        return (mixed % np.uint64(self.width)).astype(np.int64)

    # ------------------------------------------------------------- updates
    def update(self, key: str, count: int = 1) -> None:
        self.update_hashed(np.array([stable_hash64(key)], dtype=np.uint64),
                           np.array([count], dtype=np.uint64))

    def update_batch(self, keys: Iterable[str], count: int = 1) -> None:
        """Fold a batch of key occurrences (each counted ``count`` times)."""
        h = _hash_batch(list(keys))
        if h.size == 0:
            return
        uniq, cnt = np.unique(h, return_counts=True)
        self.update_hashed(uniq, cnt.astype(np.uint64) * np.uint64(count))

    def update_counted(self, counted: Dict[str, int]) -> None:
        """Fold pre-aggregated ``{key: count}`` occurrences (hashes only
        the distinct keys — the fast path when the caller already holds a
        Counter).  64-bit hash collisions between distinct keys are
        summed (they share cells anyway), keeping the no-under-count
        invariant."""
        if not counted:
            return
        h = _hash_batch(list(counted))
        c = np.fromiter(counted.values(), dtype=np.uint64, count=len(counted))
        uniq, inv = np.unique(h, return_inverse=True)
        # align counts with the (sorted) unique hashes; colliding distinct
        # keys sum their counts
        aligned = np.zeros(uniq.size, dtype=np.uint64)
        np.add.at(aligned, inv, c)
        self.update_hashed(uniq, aligned)

    def update_hashed(self, hashes: np.ndarray, counts: np.ndarray) -> None:
        """Conservative batch update for pre-hashed *distinct* keys."""
        if hashes.size == 0:
            return
        idx = self._indices(hashes)
        cur = self.table[self._rows[:, None], idx]        # (depth, n)
        target = cur.min(axis=0) + counts                 # new min estimate
        np.maximum.at(self.table, (self._rows[:, None], idx),
                      np.broadcast_to(target, cur.shape))
        self.total += int(counts.sum())

    # ------------------------------------------------------------- queries
    def query(self, key: str) -> int:
        return int(self.query_hashed(
            np.array([stable_hash64(key)], dtype=np.uint64))[0])

    def query_hashed(self, hashes: np.ndarray) -> np.ndarray:
        if hashes.size == 0:
            return np.zeros(0, dtype=np.uint64)
        idx = self._indices(hashes)
        return self.table[self._rows[:, None], idx].min(axis=0)

    # ------------------------------------------------------------- algebra
    def compatible(self, other: "CountMinSketch") -> bool:
        return (self.width == other.width and self.depth == other.depth
                and self.seed == other.seed)

    def merge(self, other: "CountMinSketch") -> "CountMinSketch":
        """Element-wise sum: estimates of the union stream still never
        under-count (min of sums >= sum of mins >= truth)."""
        if not self.compatible(other):
            raise ValueError("merging incompatible CMS geometries/seeds")
        self.table += other.table
        self.total += other.total
        return self

    def reset(self) -> None:
        self.table[:] = 0
        self.total = 0

    # --------------------------------------------------------------- wire
    def serialize(self) -> bytes:
        body = zlib.compress(self.table.tobytes(), 6)
        head = struct.pack(">4sIIIQ", _CMS_MAGIC, self.width, self.depth,
                           self.seed, self.total)
        return head + body

    @classmethod
    def deserialize(cls, data: bytes) -> "CountMinSketch":
        magic, width, depth, seed, total = struct.unpack_from(">4sIIIQ",
                                                              data)
        if magic != _CMS_MAGIC:
            raise ValueError("not a CMS payload")
        out = cls(width, depth, seed)
        table = np.frombuffer(zlib.decompress(data[struct.calcsize(
            ">4sIIIQ"):]), dtype=np.uint64).reshape(depth, width)
        out.table = table.copy()
        out.total = int(total)
        return out


class SpaceSaving:
    """Stream-Summary top-k heavy hitters (Metwally et al.).

    ``counts[key]`` over-estimates the key's true count by at most
    ``errs[key]``; any key with true count > ``total/k`` is guaranteed
    to be present.  ``merge`` follows the mergeable-summaries recipe:
    sum counts/errors for shared keys, charge the other side's minimum
    count as error for one-sided keys, then re-truncate to k.
    """

    def __init__(self, k: int = 64) -> None:
        if k < 1:
            raise ValueError("k must be >= 1")
        self.k = int(k)
        self.counts: Dict[str, int] = {}
        self.errs: Dict[str, int] = {}
        self.total = 0

    def _min_entry(self) -> Tuple[str, int]:
        key = min(self.counts, key=self.counts.__getitem__)
        return key, self.counts[key]

    def update(self, key: str, count: int = 1) -> None:
        self.total += count
        if key in self.counts:
            self.counts[key] += count
        elif len(self.counts) < self.k:
            self.counts[key] = count
            self.errs[key] = 0
        else:
            vk, vmin = self._min_entry()
            del self.counts[vk]
            del self.errs[vk]
            self.counts[key] = vmin + count
            self.errs[key] = vmin

    def update_batch(self, keys: Iterable[str]) -> None:
        from collections import Counter
        self.update_counted(Counter(keys))

    def update_counted(self, counted: Dict[str, int]) -> None:
        """Fold pre-aggregated ``{key: count}`` occurrences in one
        merge-style pass (mergeable-summaries: the batch is an *exact*
        summary, so only the table side charges its minimum to keys it
        may have evicted).  Equivalent guarantees to per-key updates —
        counts never under-estimate, ``err`` bounds the over-estimate —
        at a fraction of the cost: one sort instead of an O(k) min-scan
        per eviction."""
        if not counted:
            return
        self.total += sum(counted.values())
        amin = (min(self.counts.values())
                if len(self.counts) >= self.k else 0)
        merged: Dict[str, Tuple[int, int]] = {}
        pending = dict(counted)
        for key, c in self.counts.items():
            merged[key] = (c + pending.pop(key, 0), self.errs[key])
        for key, c in pending.items():
            merged[key] = (c + amin, amin)
        top = sorted(merged.items(), key=lambda e: -e[1][0])[:self.k]
        self.counts = {k: c for k, (c, _) in top}
        self.errs = {k: e for k, (_, e) in top}

    def query(self, key: str) -> int:
        return self.counts.get(key, 0)

    def guaranteed(self, key: str) -> int:
        """Lower bound on the key's true count (count - err)."""
        return self.counts.get(key, 0) - self.errs.get(key, 0)

    def items(self) -> List[Tuple[str, int, int]]:
        """(key, count, err) sorted by estimated count, descending."""
        return sorted(((k, c, self.errs[k]) for k, c in self.counts.items()),
                      key=lambda e: -e[1])

    def min_count(self) -> int:
        if not self.counts:
            return 0
        return min(self.counts.values())

    def merge(self, other: "SpaceSaving") -> "SpaceSaving":
        if self.k != other.k:
            raise ValueError("merging SpaceSaving summaries of different k")
        amin = self.min_count() if len(self.counts) >= self.k else 0
        bmin = other.min_count() if len(other.counts) >= other.k else 0
        merged: Dict[str, Tuple[int, int]] = {}
        for key, c in self.counts.items():
            e = self.errs[key]
            if key in other.counts:
                merged[key] = (c + other.counts[key], e + other.errs[key])
            else:
                merged[key] = (c + bmin, e + bmin)
        for key, c in other.counts.items():
            if key not in merged:
                merged[key] = (c + amin, other.errs[key] + amin)
        top = sorted(merged.items(), key=lambda e: -e[1][0])[:self.k]
        self.counts = {k: c for k, (c, _) in top}
        self.errs = {k: e for k, (_, e) in top}
        self.total += other.total
        return self

    def reset(self) -> None:
        self.counts.clear()
        self.errs.clear()
        self.total = 0

    # --------------------------------------------------------------- wire
    def serialize(self) -> bytes:
        parts = [struct.pack(">4sIIQ", _SS_MAGIC, self.k, len(self.counts),
                             self.total)]
        for key, c in self.counts.items():
            kb = key.encode("utf-8")
            parts.append(struct.pack(">HQQ", len(kb), c, self.errs[key]))
            parts.append(kb)
        return zlib.compress(b"".join(parts), 6)

    @classmethod
    def deserialize(cls, data: bytes) -> "SpaceSaving":
        raw = zlib.decompress(data)
        magic, k, n, total = struct.unpack_from(">4sIIQ", raw)
        if magic != _SS_MAGIC:
            raise ValueError("not a SpaceSaving payload")
        out = cls(k)
        off = struct.calcsize(">4sIIQ")
        for _ in range(n):
            klen, c, e = struct.unpack_from(">HQQ", raw, off)
            off += struct.calcsize(">HQQ")
            key = raw[off:off + klen].decode("utf-8")
            off += klen
            out.counts[key] = c
            out.errs[key] = e
        out.total = int(total)
        return out


class DemandSketch:
    """Per-shard ghost-hit heat: CMS + SpaceSaving fed from the cache.

    The cache calls :meth:`note` on every *ghost hit* (a miss whose block
    sits in the BufferWindow — i.e. it was evicted recently enough that
    more quota would have kept it).  Notes land in a plain list (the only
    per-access cost) and fold into both sketches in vectorized batches.

    One measurement interval spans one cross-shard round:
    ``ShardDemandTracker`` folds, reads per-stream demand via
    :meth:`distinct_under`, then :meth:`reset`\\ s the interval.
    """

    FOLD_BATCH = 4096

    def __init__(self, cfg: Optional[CacheConfig] = None,
                 width: Optional[int] = None, depth: Optional[int] = None,
                 k: Optional[int] = None, seed: int = 0) -> None:
        cfg = cfg or CacheConfig()
        self.cms = CountMinSketch(width or cfg.sketch_width,
                                  depth or cfg.sketch_depth, seed)
        self.topk = SpaceSaving(k or cfg.topk)
        self._pending: List[str] = []
        self.noted = 0          # ghost hits this interval

    # ------------------------------------------------------------ hot path
    def note(self, key: str) -> None:
        self._pending.append(key)
        if len(self._pending) >= self.FOLD_BATCH:
            self.fold()

    def fold(self) -> None:
        from collections import Counter
        batch = self._pending
        if not batch:
            return
        self._pending = []
        self.noted += len(batch)
        # aggregate once, hash only the distinct keys, and feed both
        # sketches the counted form — the fold cost is dominated by
        # per-distinct-key work, not batch length
        cnt = Counter(batch)
        self.cms.update_counted(cnt)
        self.topk.update_counted(cnt)

    # ------------------------------------------------------------- queries
    def distinct_under(self, prefix: str) -> Tuple[int, int]:
        """(distinct_head, head_mass) for keys under ``prefix``.

        ``distinct_head`` counts the tracked heavy hitters under the
        prefix; ``head_mass`` is the ghost-hit mass they account for
        (guaranteed lower bounds, so the caller's exact per-stream hit
        counter minus ``head_mass`` upper-bounds the *tail* — blocks too
        cold for the top-k, each contributing at least one hit).
        Callers turn head + tail into a working-set byte estimate.
        """
        self.fold()
        head = 0
        head_mass = 0
        for key, count, err in self.topk.items():
            if key.startswith(prefix):
                head += 1
                head_mass += max(1, count - err)
        return head, head_mass

    def reset(self) -> None:
        self._pending.clear()
        self.cms.reset()
        self.topk.reset()
        self.noted = 0

    # --------------------------------------------------------------- wire
    def serialize(self) -> Tuple[bytes, bytes]:
        self.fold()
        return self.cms.serialize(), self.topk.serialize()

"""The IGTCache engine (§3, §4): observe → recognize → adapt.

This is the **kernel layer** of the two-layer public API (docs/API.md):
one object drives the full read path:

    outcome = engine.read(file_path, offset, size, now)

``outcome`` reports, per 4 MB block, whether it was served from cache, and
carries the prefetch candidates the engine wants fetched in the background.
The *caller* owns time and bandwidth: it fetches misses/prefetches and
calls ``complete_prefetch`` when background bytes land (or
``cancel_prefetch`` for candidates it will never run — every candidate
must get one or the other).  This keeps the engine a pure, deterministic
state machine — the property-test surface.  Most consumers don't drive
the kernel by hand: the *client layer* (``core.client.CacheClient`` via
``open_cache``) owns the I/O contract and runs candidates on a pluggable
``PrefetchExecutor``; the discrete-event simulator plugs its shared-link
transport in as one of those executors.

Hot-path architecture (§4 overhead claim, Fig. 17):

  * ``read()`` is the *batched extent path*: the root→leaf level resolution
    is memoized per directory (``meta.LevelCache``), the tree walk is built
    once per file as a replayable ``ObservedChain`` and every block of the
    extent is observed by replaying it (no dict-walk), routing reuses the
    chain nodes instead of re-walking the tree, and ``tick()`` runs once per
    read instead of once per block;
  * ``read_serial()`` is the per-block reference path kept for
    cross-checking — tests/test_equivalence.py asserts both paths produce
    identical ReadOutcomes, stats and tree state on seeded mixed traces;
  * pattern analysis is vectorized: every observation window due for
    (re)classification is pushed through ``pattern.classify_batch`` in one
    matrix pass (K-S statistic, distinct-deficit z, sequential screen).

Baselines (§5) are the same engine with adaptivity switched off via
``EngineOptions`` — e.g. JuiceFS ≈ enhanced-stride readahead + one global LRU
pool + fixed TTL; see ``baselines.py`` for the named bundles.
"""
from __future__ import annotations

from collections import OrderedDict, defaultdict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .access_stream_tree import (AccessStream, AccessStreamTree,
                                 ObservedChain, analyze_streams)
from .allocation import (FluidAllocator, QuiverAllocator, Rebalancer,
                         placement_hint)
from .cache import (CacheManageUnit, SubStream, UnifiedCache, path_key)
from .eviction import EagerEviction
from .meta import LevelCache, StoreMeta
from .prefetch import (block_sequential_candidates, sequential_candidates,
                       statistical_candidates)
from .types import (CacheConfig, CacheStats, PathT, Pattern, block_key,
                    split_block_key)


@dataclass
class EngineOptions:
    """Feature switches; defaults = full IGTCache."""

    prefetch: str = "adaptive"     # adaptive|stride|enhanced_stride|sfp|none
    eviction: str = "adaptive"     # adaptive|lru|fifo|lfu|arc|sieve|uniform
    allocation: str = "adaptive"   # adaptive|shared|quiver|fluid|static
    static_fraction: float = 0.5   # for allocation == "static"
    fixed_ttl: Optional[float] = None
    name: str = "igtcache"


class BlockResult:
    """Per-block read result (slotted by hand — one is built per block on
    the hot path)."""

    __slots__ = ("key", "size", "hit", "prefetched_hit")

    def __init__(self, key: str, size: int, hit: bool,
                 prefetched_hit: bool = False) -> None:
        self.key = key
        self.size = size
        self.hit = hit
        self.prefetched_hit = prefetched_hit

    def __eq__(self, other) -> bool:
        return (isinstance(other, BlockResult)
                and self.key == other.key and self.size == other.size
                and self.hit == other.hit
                and self.prefetched_hit == other.prefetched_hit)

    def __reduce__(self):
        # positional-args reduce: ~3× cheaper than the generic slotted
        # __reduce_ex__ state dance — BlockResults cross the process
        # boundary in every multi-process-driver read_batch reply
        return (BlockResult, (self.key, self.size, self.hit,
                              self.prefetched_hit))

    def __repr__(self) -> str:  # pragma: no cover
        return (f"BlockResult({self.key!r}, {self.size}, hit={self.hit}, "
                f"pf={self.prefetched_hit})")


class ReadOutcome:
    __slots__ = ("blocks", "prefetches")

    def __init__(self, blocks: Optional[List[BlockResult]] = None,
                 prefetches: Optional[List[Tuple[PathT, int]]] = None) -> None:
        self.blocks = [] if blocks is None else blocks
        self.prefetches = [] if prefetches is None else prefetches

    def __reduce__(self):
        return (ReadOutcome, (self.blocks, self.prefetches))

    @property
    def remote_bytes(self) -> int:
        return sum(b.size for b in self.blocks if not b.hit)

    @property
    def cached_bytes(self) -> int:
        return sum(b.size for b in self.blocks if b.hit)


class _PrefixSet:
    """Pin/ban table with O(path-depth) membership (was an O(table) scan)."""

    __slots__ = ("_set", "_lens")

    def __init__(self) -> None:
        self._set: set = set()
        self._lens: Tuple[int, ...] = ()

    def add(self, prefix: PathT) -> None:
        self._set.add(prefix)
        self._lens = tuple(sorted({len(p) for p in self._set}))

    def covers(self, path: PathT) -> bool:
        s = self._set
        if not s:
            return False
        n = len(path)
        for length in self._lens:
            if length > n:
                break
            if path[:length] in s:
                return True
        return False

    def __len__(self) -> int:
        return len(self._set)

    def __iter__(self):
        return iter(self._set)


class _FileCtx:
    """Per-file read-path context: memoized geometry + replayable chain +
    generation-checked CMU resolution (§4 batched read path)."""

    __slots__ = ("file_path", "dir_levels", "fsize", "nblocks", "key_prefix",
                 "keys", "flat_start", "flat_total", "chain", "cmu",
                 "cmu_gen")

    _KEY_CACHE_MAX_BLOCKS = 512

    def __init__(self, file_path: PathT, dir_levels, fsize: int,
                 nblocks: int, key_prefix: str) -> None:
        self.file_path = file_path
        self.dir_levels = dir_levels
        self.fsize = fsize
        self.nblocks = nblocks
        self.key_prefix = key_prefix
        if nblocks <= self._KEY_CACHE_MAX_BLOCKS:
            if key_prefix:
                self.keys: Optional[Tuple[str, ...]] = tuple(
                    f"{key_prefix}/#{b}" for b in range(nblocks))
            else:
                self.keys = tuple(f"#{b}" for b in range(nblocks))
        else:
            self.keys = None
        self.flat_start = 0
        self.flat_total = -1           # -1 = not resolved yet
        self.chain: Optional[ObservedChain] = None
        self.cmu: Optional[CacheManageUnit] = None
        self.cmu_gen = -1


class IGTCache:
    def __init__(self, meta: StoreMeta, capacity: int,
                 cfg: Optional[CacheConfig] = None,
                 options: Optional[EngineOptions] = None) -> None:
        self.meta = meta
        self.cfg = cfg or CacheConfig()
        self.options = options or EngineOptions()
        self.tree = AccessStreamTree(self.cfg)
        self.cache = UnifiedCache(capacity, self.cfg)
        self.stats = self.cache.stats
        self._blocks = self.cache.blocks   # hot-path residency alias
        self.rebalancer = Rebalancer(self.cfg)
        self.quiver = QuiverAllocator(self.cfg)
        self.fluid = FluidAllocator(self.cfg)
        # memoized metadata resolution + per-file read contexts (§4)
        self.levels = LevelCache(meta)
        self._ctx_cache: "OrderedDict[PathT, _FileCtx]" = OrderedDict()
        self._ctx_cap = max(4 * self.cfg.node_cap, 4096)
        # prefetch bookkeeping
        self._pending_prefetch: set = set()
        self._prefetched_resident: set = set()
        self._node_last_prefetch_idx: Dict[PathT, int] = {}
        self._ra_depth: Dict[PathT, int] = {}
        # stride/enhanced-stride readahead state per file
        self._stride_state: Dict[PathT, Tuple[int, int, int]] = {}
        # SFP: file-level first-order Markov transitions per dataset
        self._sfp_prev: Dict[str, PathT] = {}
        self._sfp_trans: Dict[PathT, Dict[PathT, int]] = defaultdict(dict)
        self._last_ttl_sweep = 0.0
        # explicit user instructions (§3.3 footnote 8): path prefixes the
        # user pinned (never evict / never TTL) or banned (never cache)
        self._pinned = _PrefixSet()
        self._never_cache = _PrefixSet()
        # tiered-backing placement hooks (storage.tiers): a store exposing
        # note_evicted gets every kernel eviction (its spill signal), one
        # exposing note_pattern gets per-dataset placement verdicts from
        # tick().  Observation-only taps — kernel decisions never read
        # tier state, so a tiered stack stays bitwise-identical to flat.
        ev = getattr(meta, "note_evicted", None)
        if callable(ev):
            self.cache.evict_hook = ev
        self._placement_hook = getattr(meta, "note_pattern", None)
        self._placement_sent: Dict[str, Tuple[str, bool]] = {}

    # -------------------------------------------------------- user controls
    def pin(self, path: PathT) -> None:
        """Persistently cache everything under ``path`` (user override):
        exempt from TTL expiry and from allocation donation below its use."""
        self._pinned.add(path)

    def never_cache(self, path: PathT) -> None:
        """Never admit blocks under ``path`` (reads pass through)."""
        self._never_cache.add(path)

    def invalidate_meta_cache(self) -> None:
        """Call if the backing store re-registers datasets mid-run."""
        self.levels.invalidate()
        self._ctx_cache.clear()

    # ------------------------------------------------------------------ read
    def read(self, file_path: PathT, offset: int, size: int,
             now: float) -> ReadOutcome:
        """Batched extent read (§4): resolve once, observe by chain replay,
        route from the chain, tick once."""
        out = self._read_impl(file_path, offset, size, now)
        if out.blocks:
            self.tick(now)
        return out

    def _read_impl(self, file_path: PathT, offset: int, size: int,
                   now: float) -> ReadOutcome:
        out = ReadOutcome()
        ctx = self._file_ctx(file_path)
        size = max(0, min(size, ctx.fsize - offset))
        if size == 0:
            return out
        bs = self.cfg.block_size
        first, last = offset // bs, (offset + size - 1) // bs
        chain = ctx.chain
        ok = chain is not None
        if ok:
            for nd in chain.check_nodes:    # inlined chain.valid()
                if nd.detached:
                    ok = False
                    break
        if not ok:
            chain = self.tree.build_chain(ctx.dir_levels, ctx.nblocks)
            ctx.chain = chain
        if not chain.valid():
            # pathological: the build itself tripped the node cap onto this
            # very path — fall back to the reference per-block walk
            ctx.chain = None
            for b in range(first, last + 1):
                self._read_block(file_path, b, min(bs, ctx.fsize - b * bs),
                                 now, out)
        else:
            tree = self.tree
            cfg = self.cfg
            prefix = ctx.key_prefix
            keys = ctx.keys
            fsize = ctx.fsize
            due: List[AccessStream] = []
            for b in range(first, last + 1):
                bsize = min(bs, fsize - b * bs)
                del due[:]
                tree.replay_chain(chain, b, now, due)
                if due:
                    analyze_streams(due, cfg)
                cmu, sub, governing = self._route_chain(ctx, chain, b, now)
                if keys is not None:
                    key = keys[b]
                else:
                    key = f"{prefix}/#{b}" if prefix else f"#{b}"
                self._serve_block(file_path, key, bsize, cmu, sub,
                                  governing, now, out)
                cands = self._gen_prefetch_chain(ctx, chain, b, cmu,
                                                 governing, now)
                if cands:
                    out.prefetches.extend(cands)
        if self.options.prefetch == "sfp":
            self._sfp_observe(file_path, out, now)
        return out

    def read_batch(self, requests: Sequence[Tuple[PathT, int, int]],
                   now: float) -> List[ReadOutcome]:
        """Serve a batch of (file_path, offset, size) requests at one
        timestamp, running the tick/allocation cadence once for the batch."""
        outs = [self._read_impl(fp, off, sz, now)
                for fp, off, sz in requests]
        self.tick(now)
        return outs

    def read_serial(self, file_path: PathT, offset: int, size: int,
                    now: float) -> ReadOutcome:
        """Reference per-block read path (uncached walks; cross-checked
        against the batched read() by tests/test_equivalence.py)."""
        out = ReadOutcome()
        fsize = self.meta.file_size(file_path)
        size = max(0, min(size, fsize - offset))
        if size == 0:
            return out
        bs = self.cfg.block_size
        first, last = offset // bs, (offset + size - 1) // bs
        for b in range(first, last + 1):
            bsize = min(bs, fsize - b * bs)
            self._read_block(file_path, b, bsize, now, out)
        if self.options.prefetch == "sfp":
            self._sfp_observe(file_path, out, now)
        self.tick(now)
        return out

    def _read_block(self, file_path: PathT, b: int, bsize: int, now: float,
                    out: ReadOutcome) -> None:
        leaf_path = block_key(file_path, b)
        key = path_key(leaf_path)
        levels = self._resolve_levels(file_path, b)
        self.tree.observe(levels, now, bsize)
        cmu, sub, governing = self._route(file_path, leaf_path, now, b)
        self._serve_block(file_path, key, bsize, cmu, sub, governing, now,
                          out)
        out.prefetches.extend(self._gen_prefetch(file_path, leaf_path, cmu,
                                                 governing, now))

    def _serve_block(self, file_path: PathT, key: str, bsize: int,
                     cmu: CacheManageUnit, sub: SubStream,
                     governing: Optional[AccessStream], now: float,
                     out: ReadOutcome) -> None:
        """Hit/miss accounting + admission for one block (both read paths)."""
        cmu.note_access(now, bsize)
        if governing is not None and governing.ttl is not None:
            cmu.ttl = governing.ttl
        if self.options.fixed_ttl is not None:
            cmu.ttl = self.options.fixed_ttl

        stats = self.stats
        if key in self._blocks:
            stats.hits += 1
            cmu.hits += 1
            stats.bytes_from_cache += bsize
            pf_hit = key in self._prefetched_resident
            if pf_hit:
                self._prefetched_resident.discard(key)
                stats.prefetch_hits += 1
            cmu.on_hit(key)
            cmu.after_read(key)  # eager eviction for sequential streams
            out.blocks.append(BlockResult(key, bsize, True, pf_hit))
        else:
            stats.misses += 1
            cmu.misses += 1
            stats.bytes_from_remote += bsize
            cmu.on_miss(key, sub)
            # Eager (sequential) streams read demand misses *through* the
            # cache: the block is consumed on arrival, so admitting it would
            # only evict a useful readahead block (§3.3 eager eviction).
            banned = self._never_cache.covers(file_path)
            if not banned and not isinstance(sub.policy, EagerEviction):
                self.cache.insert_key(key, bsize, cmu, sub)
            out.blocks.append(BlockResult(key, bsize, False))

    # ------------------------------------------------------- path resolution
    def _file_ctx(self, file_path: PathT) -> _FileCtx:
        cache = self._ctx_cache
        ctx = cache.get(file_path)
        if ctx is None:
            fsize = self.meta.file_size(file_path)
            nblocks = max(1, -(-fsize // self.cfg.block_size))
            ctx = _FileCtx(file_path, self.levels.dir_levels(file_path),
                           fsize, nblocks, "/".join(file_path))
            cache[file_path] = ctx
            if len(cache) > self._ctx_cap:
                cache.popitem(last=False)
        return ctx

    def _resolve_levels(self, file_path: PathT, b: int):
        """Root-to-leaf (key, index, parent-listing-size); the tree applies
        layer compression internally (degenerate levels record nothing).
        Reference (uncached) form of the LevelCache resolution."""
        levels: List[Tuple[str, int, int]] = []
        for depth in range(len(file_path)):
            parent = file_path[:depth]
            name = file_path[depth]
            total = self.meta.listing_size(parent)
            idx = self.meta.child_index(parent, name)
            levels.append((name, idx, total))
        fsize = self.meta.file_size(file_path)
        nblocks = max(1, -(-fsize // self.cfg.block_size))
        levels.append((f"#{b}", b, nblocks))
        return levels

    # --------------------------------------------------------------- routing
    def _route(self, file_path: PathT, leaf_path: PathT, now: float,
               block: int):
        """Map an access to (CMU, SubStream, governing pattern node).

        Policy pattern precedence: the CMU's flattened dataset-granularity
        classification (when its window is full) overrides the per-level
        node pattern for RANDOM/SKEWED decisions — skew spread across few
        large files is only visible in the flat index space.  SEQUENTIAL
        detections at any level are kept (they carry the prefetch structure).
        """
        isolating = self.options.allocation != "shared"
        governing = self.tree.deepest_informative(leaf_path)
        if isolating:
            anchor = self.tree.shallowest_non_trivial(file_path)
            self._maybe_create_cmu(anchor, now)
        cmu = self.cache.cmu_for_path(leaf_path)
        flat = Pattern.UNKNOWN
        if cmu is not self.cache.default_cmu:
            # flat dataset-granularity view (meaningless for the default CMU,
            # which mixes unrelated datasets)
            ordinal, total = self.meta.flat_block_index(file_path, block)
            flat = cmu.note_flat(ordinal, total, now)
        return self._pick_substream(cmu, governing, flat)

    def _chain_governing(self, chain: ObservedChain) -> Optional[AccessStream]:
        """Deepest non-trivial classified chain node — the chain-scan form
        of ``tree.deepest_informative`` (shared by read and prefetch
        completion)."""
        W = self.cfg.window
        for n in reversed(chain.cnodes):
            if n.accesses >= W and n.pattern.pattern is not Pattern.UNKNOWN:
                return n
        return None

    def _resolve_ctx_cmu(self, ctx: _FileCtx) -> CacheManageUnit:
        """Per-file CMU resolution, cached until the CMU registry changes."""
        cache = self.cache
        if ctx.cmu is None or ctx.cmu_gen != cache.cmu_gen:
            ctx.cmu = cache.cmu_for_path(ctx.file_path)
            ctx.cmu_gen = cache.cmu_gen
        return ctx.cmu

    def _route_chain(self, ctx: _FileCtx, chain: ObservedChain, block: int,
                     now: float):
        """Chain-replay form of :meth:`_route`: the governing/anchor walks
        become scans over the (already resolved) chain nodes."""
        governing = self._chain_governing(chain)
        if self.options.allocation != "shared":
            W = self.cfg.window
            anchor = None
            for n in chain.cnodes:
                if n.accesses >= W:
                    anchor = n
                    break
            self._maybe_create_cmu(anchor, now)
        cmu = self._resolve_ctx_cmu(ctx)
        cache = self.cache
        flat = Pattern.UNKNOWN
        if cmu is not cache.default_cmu:
            if ctx.flat_total < 0:
                ctx.flat_start, ctx.flat_total = \
                    self.meta.flat_block_index(ctx.file_path, 0)
            flat = cmu.note_flat(ctx.flat_start + block, ctx.flat_total, now)
        return self._pick_substream(cmu, governing, flat)

    def _maybe_create_cmu(self, anchor: Optional[AccessStream],
                          now: float) -> None:
        if anchor is None or anchor.path in self.cache.cmus:
            return
        cmu = self.cache.create_cmu(
            anchor.path, self.meta.subtree_bytes(anchor.path), now)
        if self.options.allocation == "static":
            want = int(self.options.static_fraction *
                       max(1, cmu.dataset_bytes))
            self._set_static_quota(cmu, want)
        elif self.options.allocation == "adaptive":
            # late arrivals get their minimum share immediately
            self.rebalancer.seed(cmu, list(self.cache.cmus.values()))

    def _pick_substream(self, cmu: CacheManageUnit,
                        governing: Optional[AccessStream], flat: Pattern):
        pattern = Pattern.UNKNOWN
        gpath = cmu.root_path
        if governing is not None:
            pattern = governing.pattern.pattern
            gpath = governing.path
        if flat is not Pattern.UNKNOWN and pattern is not Pattern.SEQUENTIAL:
            pattern = flat
            gpath = cmu.root_path
        if self.options.eviction != "adaptive":
            sub = self._fixed_substream(cmu)
        else:
            sub = cmu.substream(gpath, pattern)
        return cmu, sub, governing

    def _fixed_substream(self, cmu: CacheManageUnit) -> SubStream:
        from .eviction import make_policy
        sub = cmu.substreams.get(cmu.root_path)
        if sub is None or getattr(sub.policy, "name", "") != self.options.eviction:
            cap_blocks = max(1, cmu.quota // self.cfg.block_size)
            policy = make_policy(self.options.eviction, cap_blocks)
            if sub is not None:
                for k in sub.blocks:
                    policy.record_insert(k)
                sub.policy = policy
            else:
                sub = SubStream(cmu.root_path, Pattern.UNKNOWN, policy)
                cmu.substreams[cmu.root_path] = sub
        return sub

    def _set_static_quota(self, cmu: CacheManageUnit, want: int) -> None:
        default = self.cache.default_cmu
        extra = want - cmu.quota
        if extra > 0:
            take = min(extra, max(0, default.quota - self.cfg.min_share))
            default.set_quota(default.quota - take)
            cmu.set_quota(cmu.quota + take)

    # ------------------------------------------------------------- prefetch
    def _gen_prefetch(self, file_path: PathT, leaf_path: PathT,
                      cmu: CacheManageUnit, governing: Optional[AccessStream],
                      now: float) -> List[Tuple[PathT, int]]:
        mode = self.options.prefetch
        if mode == "none" or self.cache.capacity <= 0:
            return []
        if mode in ("stride", "enhanced_stride"):
            return self._stride_prefetch(file_path, int(leaf_path[-1][1:]),
                                         enhanced=(mode == "enhanced_stride"))
        if mode == "sfp":
            return []  # handled at file switch in read()
        # -------- adaptive (IGTCache §3.3) --------
        cands: List[Tuple[PathT, int]] = []
        budget = min(cmu.quota, self.cfg.prefetch_budget_bytes)
        # sequential levels: hierarchical prefetch at every sequential node
        node = self.tree.root
        for comp in leaf_path:
            child = node.children.get(comp)
            if child is None:
                break
            self._seq_node_candidates(child, budget, cands)
            node = child
        self._stat_candidates(cmu, cands)
        return self._dedup_prefetch(cands)

    def _gen_prefetch_chain(self, ctx: _FileCtx, chain: ObservedChain,
                            block: int, cmu: CacheManageUnit,
                            governing: Optional[AccessStream],
                            now: float) -> List[Tuple[PathT, int]]:
        mode = self.options.prefetch
        if mode == "none" or self.cache.capacity <= 0:
            return []
        if mode in ("stride", "enhanced_stride"):
            return self._stride_prefetch(ctx.file_path, block,
                                         enhanced=(mode == "enhanced_stride"))
        if mode == "sfp":
            return []
        cands: List[Tuple[PathT, int]] = []
        budget = None
        window = self.cfg.window
        seq = Pattern.SEQUENTIAL
        for child in chain.cnodes:
            # inline gate (hot path): only sequential non-trivial nodes with
            # a recorded window generate candidates
            if (child.accesses >= window and child.count
                    and child.pattern.pattern is seq):
                if budget is None:
                    budget = min(cmu.quota, self.cfg.prefetch_budget_bytes)
                self._seq_node_candidates(child, budget, cands)
        self._stat_candidates(cmu, cands)
        if not cands:
            return cands
        return self._dedup_prefetch(cands)

    def _seq_node_candidates(self, child: AccessStream, budget: int,
                             cands: List[Tuple[PathT, int]]) -> None:
        """Sequential readahead at one tree level (shared by both paths).

        Readahead horizon: bounded by the stream's quota (admission will
        evict consumed/stale blocks as needed) and the global horizon cap.
        """
        if not (child.non_trivial(self.cfg)
                and child.pattern.pattern is Pattern.SEQUENTIAL
                and child.count):
            return
        idx = child.last_index
        if self._node_last_prefetch_idx.get(child.path) == idx:
            return
        self._node_last_prefetch_idx[child.path] = idx
        # Adaptive depth: double while the stream keeps advancing
        # (fast consumers outrun a fixed N=4 window).
        depth = self._ra_depth.get(child.path, self.cfg.prefetch_depth)
        if self.meta.is_file(child.path):
            got = block_sequential_candidates(
                self.meta, child, self.cfg, budget, depth=depth)
        else:
            got = sequential_candidates(
                self.meta, child, self.cfg, budget, depth=depth)
        if got:
            self._ra_depth[child.path] = min(
                depth * 2, self.cfg.max_readahead_items)
        cands.extend(got)

    def _stat_candidates(self, cmu: CacheManageUnit,
                         cands: List[Tuple[PathT, int]]) -> None:
        # random: statistical whole-dataset prefetch, once per (re)classify
        if (not cmu.stat_prefetch_done
                and cmu.effective_pattern() is Pattern.RANDOM):
            cmu.stat_prefetch_done = True
            cands.extend(statistical_candidates(
                self.meta, cmu.root_path, cmu.quota, cmu.dataset_bytes,
                self.cfg, lambda p: self.cache.resident(path_key(p))))

    def _stride_prefetch(self, file_path: PathT, b: int,
                         enhanced: bool) -> List[Tuple[PathT, int]]:
        """JuiceFS-style block readahead within one file."""
        last, run, depth = self._stride_state.get(file_path, (-2, 0, 4))
        if b == last + 1:
            run += 1
            if enhanced and run % 4 == 0:
                depth = min(32, depth * 2)
        else:
            run, depth = 0, 4
        self._stride_state[file_path] = (b, run, depth)
        if run < 3:
            return []
        fsize = self.meta.file_size(file_path)
        nblocks = max(1, -(-fsize // self.cfg.block_size))
        cands = []
        for nb in range(b + 1, min(nblocks, b + 1 + depth)):
            bsize = min(self.cfg.block_size, fsize - nb * self.cfg.block_size)
            cands.append((block_key(file_path, nb), bsize))
        return self._dedup_prefetch(cands)

    def _sfp_observe(self, file_path: PathT, out: ReadOutcome,
                     now: float) -> List[Tuple[PathT, int]]:
        """SFP [76]-style file-level Markov prefetch (baseline)."""
        ds = file_path[0] if file_path else ""
        prev = self._sfp_prev.get(ds)
        cands: List[Tuple[PathT, int]] = []
        if prev is not None and prev != file_path:
            t = self._sfp_trans[prev]
            t[file_path] = t.get(file_path, 0) + 1
            succ = self._sfp_trans.get(file_path)
            if succ:
                best, cnt = max(succ.items(), key=lambda kv: kv[1])
                total = sum(succ.values())
                if cnt >= 2 and cnt / total >= 0.5:
                    fsize = self.meta.file_size(best)
                    nblocks = max(1, -(-fsize // self.cfg.block_size))
                    for nb in range(min(nblocks, 8)):
                        bsize = min(self.cfg.block_size,
                                    fsize - nb * self.cfg.block_size)
                        cands.append((block_key(best, nb), bsize))
        self._sfp_prev[ds] = file_path
        got = self._dedup_prefetch(cands)
        out.prefetches.extend(got)
        return got

    def _dedup_prefetch(self, cands: List[Tuple[PathT, int]]):
        out = []
        for path, size in cands:
            key = path_key(path)
            if key in self._pending_prefetch or self.cache.resident(key):
                continue
            self._pending_prefetch.add(key)
            self.stats.prefetch_issued += 1
            out.append((path, size))
        return out

    def complete_prefetch(self, path: PathT, size: int, now: float) -> bool:
        """Background fetch landed — admit without polluting the tree."""
        key = path_key(path)
        self._pending_prefetch.discard(key)
        if self.cache.resident(key):
            return True
        file_path, _ = split_block_key(path)
        ctx = self._file_ctx(file_path)
        cmu = self._resolve_ctx_cmu(ctx)
        chain = ctx.chain
        if chain is not None and chain.valid():
            governing = self._chain_governing(chain)
        else:
            governing = self.tree.deepest_informative(path)
        pattern = governing.pattern.pattern if governing else Pattern.UNKNOWN
        gpath = governing.path if governing else cmu.root_path
        if self.options.eviction != "adaptive":
            sub = self._fixed_substream(cmu)
        else:
            sub = cmu.substream(gpath, pattern)
        ok = self.cache.insert_key(key, size, cmu, sub)
        if ok:
            self._prefetched_resident.add(key)
        else:
            self.stats.prefetch_wasted += 1
        return ok

    def cancel_prefetch(self, path: PathT) -> None:
        self._pending_prefetch.discard(path_key(path))

    # ------------------------------------------------------------------ tick
    def tick(self, now: float) -> None:
        """Scheduled maintenance: TTL sweep + allocation round.

        Runs once per read()/read_batch() and on the caller's own cadence
        (the simulator's 5 s event) — never per block (§4).
        """
        # TTL sweep (rate-limited).  Eviction exists to free space for other
        # active workloads (§3.3) — so it only fires under cache pressure.
        if now - self._last_ttl_sweep >= 5.0:
            self._last_ttl_sweep = now
            pressure = self.cache.used_bytes() > 0.85 * self.cache.capacity
            for path, cmu in list(self.cache.cmus.items()):
                if cmu is self.cache.default_cmu:
                    continue
                if self._pinned.covers(path):
                    continue  # user-pinned: exempt from TTL expiry
                ttl = (self.options.fixed_ttl if self.options.fixed_ttl
                       is not None else cmu.effective_ttl())
                if ttl is None:
                    continue
                idle_since = max(cmu.last_access_time, cmu.created_at)
                if pressure and now - idle_since > ttl and cmu.used > 0:
                    self.cache.remove_cmu(path)
        # allocation round (list materialization only when a round fires)
        alloc = self.options.allocation
        if alloc == "adaptive":
            if self.rebalancer.due(now):
                self.rebalancer.rebalance(list(self.cache.cmus.values()), now)
        elif alloc == "quiver":
            if self.quiver.due(now):
                self.quiver.rebalance(self.workload_cmus(), now,
                                      self._workload_capacity())
                self._give_rest_to_default()
        elif alloc == "fluid":
            if self.fluid.due(now):
                self.fluid.rebalance(self.workload_cmus(), now,
                                     self._workload_capacity())
                self._give_rest_to_default()
        if self._placement_hook is not None:
            self._emit_placement(now)

    def _emit_placement(self, now: float) -> None:
        """Push changed per-dataset placement verdicts to a tiered
        backing store (``meta.note_pattern``).  Change-detected so the
        steady state costs one dict probe per stream per tick."""
        hook = self._placement_hook
        for path, cmu in self.cache.cmus.items():
            if cmu is self.cache.default_cmu:
                continue
            hint = placement_hint(cmu, now, self.cfg)
            cur = (hint.pattern.value, hint.pin_ram)
            top = path[0]
            if self._placement_sent.get(top) != cur:
                self._placement_sent[top] = cur
                hook(top, hint.pattern.value, hint.pin_ram)

    def workload_cmus(self) -> List[CacheManageUnit]:
        """Non-default CacheManageUnits of this engine (shard-local view;
        the ShardedIGTCache facade merges these across shards for
        cluster-wide allocation)."""
        return [c for _, c in self.iter_workload_cmus()]

    def iter_workload_cmus(self):
        """(root_path, CMU) pairs for every workload stream — the uniform
        accessor shared with ShardedIGTCache (sim tracing, examples)."""
        default = self.cache.default_cmu
        for path, cmu in self.cache.cmus.items():
            if cmu is not default:
                yield path, cmu

    def _workload_capacity(self) -> int:
        return self.cache.capacity - self.cfg.min_share  # default keeps a floor

    def _give_rest_to_default(self) -> None:
        rest = self.cache.capacity - sum(
            c.quota for c in self.cache.cmus.values()
            if c is not self.cache.default_cmu)
        self.cache.default_cmu.set_quota(max(0, rest))

    # ----------------------------------------------------------------- stats
    def hit_ratio(self) -> float:
        return self.stats.hit_ratio

    def snapshot(self) -> dict:
        s = self.stats.snapshot()
        s["nodes"] = self.tree.node_count()
        s["cmus"] = len(self.cache.cmus) - 1
        s["used_bytes"] = self.cache.used_bytes()
        return s

    # ---------------------------------------------------------- warm restart
    def warm_state(self) -> dict:
        """Serializable hot-state manifest for warm restart
        (``daemon.journal``): CMU roots/quotas, resident block keys,
        sticky pin/ban prefixes, and the per-dataset placement verdicts
        already pushed to a tiered store.  Metadata only — the kernel
        never held payload bytes, so :meth:`warm_admit` on a fresh
        engine reproduces the residency exactly."""
        cmus = [{"root": tuple(path), "quota": int(cmu.quota),
                 "dataset_bytes": int(cmu.dataset_bytes)}
                for path, cmu in self.iter_workload_cmus()]
        return {
            "cmus": cmus,
            "resident": [(key, int(size))
                         for key, (size, _c) in self.cache.blocks.items()],
            "pins": [tuple(p) for p in self._pinned],
            "never_cache": [tuple(p) for p in self._never_cache],
            "verdicts": dict(self._placement_sent),
        }

    def warm_admit(self, state: dict, now: float) -> dict:
        """Re-admit a :meth:`warm_state` manifest into this (fresh)
        engine: recreate CMUs with their journaled quotas, replay
        pins/bans, re-push placement verdicts (a tiered backing store
        regains its hints before the first read), and re-insert the
        resident keys — bytes arrive from the backing store on the
        first hit's fetch, as for any metadata hit.  Idempotent; banned
        or unadmittable keys are skipped, not errors.  Returns restore
        counters."""
        restored = {"cmus": 0, "blocks": 0, "bytes": 0, "pins": 0,
                    "verdicts": 0, "skipped": 0}
        for p in state.get("pins", ()):
            self.pin(tuple(p))
            restored["pins"] += 1
        for p in state.get("never_cache", ()):
            self.never_cache(tuple(p))
        for row in state.get("cmus", ()):
            root = tuple(row["root"])
            cmu = self.cache.cmus.get(root)
            if cmu is None:
                db = int(row.get("dataset_bytes") or 0)
                if db <= 0:
                    try:
                        db = self.meta.subtree_bytes(root)
                    except Exception:
                        db = 0
                cmu = self.cache.create_cmu(root, db, now)
                restored["cmus"] += 1
            want = int(row.get("quota", 0))
            if want > cmu.quota:
                self._set_static_quota(cmu, want)
        for top, verdict in (state.get("verdicts") or {}).items():
            pattern, pin_ram = verdict
            self._placement_sent[str(top)] = (str(pattern), bool(pin_ram))
            if self._placement_hook is not None:
                self._placement_hook(str(top), str(pattern), bool(pin_ram))
            restored["verdicts"] += 1
        for key, size in state.get("resident", ()):
            if self.cache.resident(key):
                continue
            path = tuple(key.split("/"))
            file_path, b = split_block_key(path)
            if b is None or self._never_cache.covers(file_path):
                restored["skipped"] += 1
                continue
            cmu = self.cache.cmu_for_path(path)
            sub = cmu.substream(cmu.root_path, Pattern.UNKNOWN)
            if self.cache.insert_key(key, int(size), cmu, sub):
                restored["blocks"] += 1
                restored["bytes"] += int(size)
            else:
                restored["skipped"] += 1
        return restored


def informative_depth(levels: List[Tuple[str, int, int]]) -> int:
    """Deepest level index with an informative (>1 entry) listing — the depth
    to which the AccessStreamTree materializes nodes (layer compression §4)."""
    last = -1
    for d, (_, _, total) in enumerate(levels):
        if total > 1:
            last = d
    return last

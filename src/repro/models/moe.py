"""Top-k MoE with expert parallelism (sort-based dropping dispatch).

Tokens are routed to their top-k experts, sorted by expert id, packed into a
capacity-bounded (E, C, d) buffer (overflow dropped — GShard-style), run
through the expert SwiGLU as grouped einsums with the expert dim sharded over
the ``model`` mesh axis, and scattered back weighted by the (renormalized)
router probabilities.  The gather/scatter across the token(data)×expert(model)
sharding boundary is where XLA inserts the all-to-all — visible in the
dry-run's collective table.

Also returns the load-balancing auxiliary loss (Switch-style f·P dot).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..kernels import rmsnorm
from ..sharding.context import constrain
from .config import ModelConfig
from .params import p


def moe_specs(cfg: ModelConfig, layers: int, prefix_axes=("layers",)):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    L, la = (layers,), prefix_axes
    return {
        "ffn_norm": p(L + (d,), la + ("norm",), init="ones"),
        "router": p(L + (d, E), la + ("embed_noshard", "experts")),
        "w_gate": p(L + (E, d, f), la + ("experts", "embed", "ffn")),
        "w_up": p(L + (E, d, f), la + ("experts", "embed", "ffn")),
        "w_down": p(L + (E, f, d), la + ("experts", "ffn", "embed")),
    }


def moe_ffn(x: jax.Array, lp, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    h = rmsnorm(x, lp["ffn_norm"], cfg.norm_eps).reshape(T, d)

    logits = (h @ lp["router"]).astype(jnp.float32)       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance loss: E * f · P
    dispatch_frac = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(
        1.0 / (T * k))
    importance = probs.mean(axis=0)
    aux = E * jnp.sum(dispatch_frac * importance)

    # capacity per expert (static).  Tiny token counts (decode steps) get
    # drop-free capacity — dropping at serving time corrupts outputs.
    C = max(1, int(cfg.capacity_factor * T * k / E))
    C = min(C, T)
    if T <= 4 * E:
        C = min(T, max(C, k))
        C = T if T <= E else C

    flat_e = top_i.reshape(-1)                            # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # position within each expert's segment
    seg_start = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(T * k) - seg_start
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)           # E*C = drop row

    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].add(
        h[st] * keep[:, None].astype(h.dtype))
    # expert-major dispatch buffer: experts over the model axis; the
    # capacity dim optionally shards over data ("moe_capacity" rule) so the
    # expert GEMMs see per-chip capacity, not global (§Perf cell A)
    eh = constrain(buf[:E * C].reshape(E, C, d),
                   ("act_experts", "moe_capacity", None))

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eh, lp["w_gate"]
                               ).astype(jnp.float32)).astype(x.dtype)
    u = jnp.einsum("ecd,edf->ecf", eh, lp["w_up"])
    o = jnp.einsum("ecf,efd->ecd", g * u, lp["w_down"])   # (E, C, d)
    o = constrain(o, ("act_experts", "moe_capacity", None))

    flat_o = jnp.concatenate(
        [o.reshape(E * C, d), jnp.zeros((1, d), o.dtype)], axis=0)[slot]
    out = jnp.zeros((T, d), x.dtype).at[st].add(
        flat_o * (sw * keep).astype(x.dtype)[:, None])
    return out.reshape(B, S, d), aux

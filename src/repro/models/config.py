"""Model configuration for the assigned architecture pool.

One ``ModelConfig`` covers all six families (dense / moe / vlm / hybrid /
audio / ssm); family-specific fields are zero/None when unused.  Input
shapes are the four assigned (seq_len × global_batch) cells.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | vlm | hybrid | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int              # 0 for attention-free (ssm)
    n_kv_heads: int
    d_ff: int                 # dense FFN hidden (per-expert hidden for MoE)
    vocab: int
    head_dim: int = 0         # default d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    # hybrid (zamba2): a weight-shared attention block every k-th layer
    shared_attn_every: int = 0
    # vlm (llama-3.2-vision): cross-attention to image tokens every k-th layer
    cross_attn_every: int = 0
    n_image_tokens: int = 1601   # 1 tile of 448x448 @ patch 14 (+cls)
    # audio (musicgen): EnCodec codebooks (frontend stub sums embeddings)
    n_codebooks: int = 0
    # which shapes this arch skips (noted in DESIGN.md)
    skip_shapes: Tuple[str, ...] = ()

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(1, self.n_heads)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = emb
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            per = (d * (2 * d_in + 2 * self.ssm_state + d_in // self.ssm_head_dim)
                   + d_in * d + d_in)
            total += L * per
        else:
            hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
            attn = d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
            if self.family == "moe" and self.n_experts:
                ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            else:
                ffn = 3 * d * self.d_ff
            per = attn + ffn + 2 * d
            if self.family == "hybrid":
                # mamba layers + one shared attention block
                d_in = self.ssm_expand * d
                per = (d * (2 * d_in + 2 * self.ssm_state + d_in // self.ssm_head_dim)
                       + d_in * d + 2 * d)
                total += attn + 3 * d * self.d_ff  # the shared block
            total += L * per
            if self.family == "vlm" and self.cross_attn_every:
                n_ca = L // self.cross_attn_every
                total += n_ca * (attn + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Active (per-token) params: MoE counts top_k experts only."""
        if self.family != "moe" or not self.n_experts:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        full = self.param_count()
        moe_all = L * self.n_experts * 3 * d * self.d_ff
        moe_active = L * self.top_k * 3 * d * self.d_ff
        return full - moe_all + moe_active

    def shapes(self):
        for s in SHAPES.values():
            if s.name not in self.skip_shapes:
                yield s

"""Mamba2 (SSD) block: in-proj → causal depthwise conv → SSD → gated norm →
out-proj.  Single B/C group shared across heads (G=1), per the Mamba2 paper.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import gated_rmsnorm, rmsnorm, ssd, ssd_decode
from .config import ModelConfig
from .params import p


def ssm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    n = cfg.ssm_state
    conv_ch = d_in + 2 * n
    return d_in, nh, n, conv_ch


def ssm_specs(cfg: ModelConfig, layers: int, prefix_axes=("layers",)):
    d = cfg.d_model
    d_in, nh, n, conv_ch = ssm_dims(cfg)
    L, la = (layers,), prefix_axes
    return {
        "norm": p(L + (d,), la + ("norm",), init="ones"),
        "in_proj": p(L + (d, 2 * d_in + 2 * n + nh), la + ("embed", "ssm_inner")),
        "conv_w": p(L + (cfg.conv_width, conv_ch), la + ("conv", "ssm_inner"),
                    scale=1.0),
        "A_log": p(L + (nh,), la + ("ssm_heads",), init="zeros"),
        "dt_bias": p(L + (nh,), la + ("ssm_heads",), init="zeros"),
        "D": p(L + (nh,), la + ("ssm_heads",), init="ones"),
        "out_norm": p(L + (d_in,), la + ("ssm_inner",), init="ones"),
        "out_proj": p(L + (d_in, d), la + ("ssm_inner", "embed")),
    }


def _split_proj(proj, cfg):
    d_in, nh, n, _ = ssm_dims(cfg)
    z = proj[..., :d_in]
    xs = proj[..., d_in:2 * d_in]
    B_ = proj[..., 2 * d_in:2 * d_in + n]
    C_ = proj[..., 2 * d_in + n:2 * d_in + 2 * n]
    dt = proj[..., 2 * d_in + 2 * n:]
    return z, xs, B_, C_, dt


def _causal_conv(x, w, conv_state=None):
    """Depthwise causal conv. x: (B, S, ch); w: (W, ch).

    With ``conv_state`` (B, W-1, ch) prepended (decode), returns the last S
    outputs and the new state."""
    W = w.shape[0]
    if conv_state is not None:
        x = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
        new_state = x[:, -(W - 1):]
        pad = 0
    else:
        new_state = x[:, -(W - 1):]
        pad = W - 1
    out = jax.lax.conv_general_dilated(
        x, w[:, None, :].astype(x.dtype),
        window_strides=(1,), padding=[(pad, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    # valid conv over the prepended state already yields exactly S outputs
    return out, new_state


def mamba_block(x, lp, cfg: ModelConfig, *, state=None):
    """x: (B, S, d).  state = (conv_state, ssd_state) for decode (S=1).
    Returns (residual-added output, new_state_or_None)."""
    B, S, d = x.shape
    d_in, nh, n, conv_ch = ssm_dims(cfg)
    hd = cfg.ssm_head_dim
    h = rmsnorm(x, lp["norm"], cfg.norm_eps)
    proj = h @ lp["in_proj"]
    z, xs, B_, C_, dt = _split_proj(proj, cfg)

    xbc = jnp.concatenate([xs, B_, C_], axis=-1)
    conv_state = state[0] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, lp["conv_w"], conv_state)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xs, B_, C_ = (xbc[..., :d_in], xbc[..., d_in:d_in + n],
                  xbc[..., d_in + n:])

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + lp["dt_bias"].astype(jnp.float32))     # (B,S,nh)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))                 # (nh,)
    xh = xs.reshape(B, S, nh, hd)
    x_dt = (xh.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    a = dt * A

    if state is None:
        y, _final = ssd(x_dt, a, B_, C_, chunk=cfg.ssm_chunk)
        new_state = None
    else:
        ssd_state = state[1]
        y_t, new_ssd = ssd_decode(x_dt[:, 0], a[:, 0], B_[:, 0], C_[:, 0],
                                  ssd_state)
        y = y_t[:, None]
        new_state = (new_conv, new_ssd)
    y = y + lp["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = gated_rmsnorm(y, z, lp["out_norm"], cfg.norm_eps)
    out = y @ lp["out_proj"]
    return x + out, new_state


def init_ssm_state(cfg: ModelConfig, batch: int):
    d_in, nh, n, conv_ch = ssm_dims(cfg)
    conv_state = jnp.zeros((batch, cfg.conv_width - 1, conv_ch), jnp.bfloat16)
    ssd_state = jnp.zeros((batch, nh, cfg.ssm_head_dim, n), jnp.float32)
    return conv_state, ssd_state

"""Parameter specification: shapes + logical sharding axes + initializers.

A model is described as a pytree of ``ParamSpec``; the same tree materializes
three ways:
  * ``init(rng)``        — real arrays (CPU smoke tests / examples);
  * ``abstract()``       — ``jax.ShapeDtypeStruct`` (dry-run, no allocation);
  * ``shardings(mesh, rules)`` — ``NamedSharding`` per param via the logical
    axis rules (``repro.sharding.rules``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]        # logical axis names, len == ndim
    init: str = "normal"                   # normal | zeros | ones | scaled
    scale: float = 1.0
    dtype: jnp.dtype = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def p(shape, axes, init="normal", scale=1.0, dtype=jnp.bfloat16) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), init, scale, dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_abstract(specs):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=is_spec)


def tree_init(specs, rng: jax.Array):
    """Materialize real parameters (host-side, for small/smoke models)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for spec, key in zip(leaves, keys):
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, spec.dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, spec.dtype)
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = spec.scale / math.sqrt(max(1, fan_in))
            arr = (jax.random.normal(key, spec.shape, jnp.float32) * std
                   ).astype(spec.dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def tree_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)

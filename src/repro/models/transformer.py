"""The unified multi-family model: specs, forward, decode.

One code path covers all six assigned families:

  dense/audio : scan(attention + SwiGLU)
  moe         : scan(attention + top-k expert FFN), aux loss accumulated
  vlm         : + gated cross-attention to (stub) image embeddings every
                ``cross_attn_every`` layers
  ssm         : scan(Mamba2 SSD block)
  hybrid      : scan(Mamba2 block + weight-SHARED attention/MLP block fired
                every ``shared_attn_every`` layers — the Zamba2 design)

Layer stacks are scanned (`jax.lax.scan`) over stacked params so HLO size is
O(1) in depth; per-layer remat (`jax.checkpoint`) is configurable.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import decode_attention, flash_attention, rmsnorm
from ..sharding.context import constrain
from .config import ModelConfig
from .layers import apply_rope, attention, attention_specs, mlp_specs, swiglu
from .moe import moe_ffn, moe_specs
from .params import p, tree_abstract, tree_init
from .ssm import init_ssm_state, mamba_block, ssm_dims, ssm_specs

REMAT_POLICIES = {
    "none": None,
    "full": "full",
    "dots": "dots",
}


# ------------------------------------------------------------------ specs

def build_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, L = cfg.d_model, cfg.n_layers
    specs: Dict[str, Any] = {
        "embed": p((cfg.vocab, d), ("embed_vocab", "embed"), scale=1.0),
        "final_norm": p((d,), ("norm",), init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = p((d, cfg.vocab), ("embed", "vocab"))
    if cfg.family in ("dense", "audio", "vlm"):
        specs["blocks"] = {**attention_specs(cfg, L), **mlp_specs(cfg, L)}
    elif cfg.family == "moe":
        specs["blocks"] = {**attention_specs(cfg, L), **moe_specs(cfg, L)}
    elif cfg.family == "ssm":
        specs["blocks"] = ssm_specs(cfg, L)
    elif cfg.family == "hybrid":
        specs["blocks"] = ssm_specs(cfg, L)
        shared = {**attention_specs(cfg, 1), **mlp_specs(cfg, 1)}
        specs["shared"] = {k: p(v.shape[1:], v.axes[1:], v.init, v.scale)
                           for k, v in shared.items()}
    else:
        raise ValueError(cfg.family)
    if cfg.family == "vlm":
        n_ca = cfg.n_layers // cfg.cross_attn_every
        ca = attention_specs(cfg, n_ca)
        ca = {f"ca_{k}": v for k, v in ca.items()}
        ca["ca_gate"] = p((n_ca,), ("layers",), init="zeros")
        specs["cross"] = ca
    return specs


def init_params(cfg: ModelConfig, rng: jax.Array):
    return tree_init(build_specs(cfg), rng)


def abstract_params(cfg: ModelConfig):
    return tree_abstract(build_specs(cfg))


# ------------------------------------------------------------- sub-blocks

def _cross_attention(x, cap, cfg: ModelConfig, img_kv):
    """Gated cross-attention to precomputed image K/V (one layer's params)."""
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rmsnorm(x, cap["ca_attn_norm"], cfg.norm_eps)
    q = (h @ cap["ca_wq"]).reshape(B, S, H, hd)
    k, v = img_kv                                     # (B, n_img, KV, hd)
    attn = flash_attention(q, k, v, causal=False)
    out = attn.reshape(B, S, H * hd) @ cap["ca_wo"]
    gate = jnp.tanh(cap["ca_gate"].astype(jnp.float32)).astype(x.dtype)
    return out * gate


def _image_kv(cap_stacked, cfg: ModelConfig, img_embeds):
    """Precompute cross-attention K/V for all cross layers: (L_ca, B, n, KV, hd)."""
    B, n_img, d = img_embeds.shape
    KV, hd = cfg.n_kv_heads, cfg.hd

    def one(cap):
        k = (img_embeds @ cap["ca_wk"]).reshape(B, n_img, KV, hd)
        v = (img_embeds @ cap["ca_wv"]).reshape(B, n_img, KV, hd)
        return k, v

    return jax.vmap(one)(
        {k: v for k, v in cap_stacked.items() if k in ("ca_wk", "ca_wv")})


def _shared_block(x, sp, cfg: ModelConfig, positions, cache=None,
                  cache_len=None):
    """Zamba2 weight-shared attention+MLP block (params have no layer dim)."""
    lp = {k: v for k, v in sp.items()}
    out, new_cache = attention(x, lp, cfg, positions=positions, cache=cache,
                               cache_len=cache_len)
    x = x + out
    x = x + swiglu(x, lp, cfg)
    return x, new_cache


# ------------------------------------------------------------------ forward

def forward(params, cfg: ModelConfig, tokens=None, *, inputs_embeds=None,
            img_embeds=None, remat: str = "full",
            unroll: int = 1,
            return_hidden: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward (train / prefill).  Returns (logits, aux_loss)."""
    if inputs_embeds is not None:
        x = inputs_embeds.astype(jnp.bfloat16)
    else:
        x = params["embed"][tokens]
    x = constrain(x, ("batch", "seq", "act_embed"))
    B, S, d = x.shape
    positions = jnp.arange(S)
    aux0 = jnp.zeros((), jnp.float32)

    img_kv = None
    if cfg.family == "vlm":
        if img_embeds is None:
            img_embeds = jnp.zeros((B, cfg.n_image_tokens, d), x.dtype)
        img_kv = _image_kv(params["cross"], cfg, img_embeds)

    def dense_body(carry, scanned):
        x, aux = carry
        lp, idx = scanned["lp"], scanned["idx"]
        out, _ = attention(x, lp, cfg, positions=positions)
        x = constrain(x + out, ("batch", "seq", "act_embed"))
        if cfg.family == "moe":
            ffn, a = moe_ffn(x, lp, cfg)
            aux = aux + a
        else:
            ffn = swiglu(x, lp, cfg)
        x = constrain(x + ffn, ("batch", "seq", "act_embed"))
        if cfg.family == "vlm":
            def with_ca(x):
                ca_idx = idx // cfg.cross_attn_every
                cap = jax.tree.map(
                    lambda a: lax.dynamic_index_in_dim(a, ca_idx, 0, False),
                    params["cross"])
                kv = jax.tree.map(
                    lambda a: lax.dynamic_index_in_dim(a, ca_idx, 0, False),
                    img_kv)
                return x + _cross_attention(x, cap, cfg, kv)
            x = lax.cond(idx % cfg.cross_attn_every == 0, with_ca,
                         lambda x: x, x)
        return (x, aux), None

    def ssm_body(carry, scanned):
        x, aux = carry
        lp, idx = scanned["lp"], scanned["idx"]
        x, _ = mamba_block(x, lp, cfg)
        x = constrain(x, ("batch", "seq", "act_embed"))
        if cfg.family == "hybrid":
            x = lax.cond(
                idx % cfg.shared_attn_every == 0,
                lambda x: _shared_block(x, params["shared"], cfg,
                                        positions)[0],
                lambda x: x, x)
        return (x, aux), None

    body = ssm_body if cfg.family in ("ssm", "hybrid") else dense_body
    if remat != "none":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if remat == "dots" else None)
        body = jax.checkpoint(body, policy=policy)

    scanned = {"lp": params["blocks"], "idx": jnp.arange(cfg.n_layers)}
    (x, aux), _ = lax.scan(body, (x, aux0), scanned, unroll=unroll)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, aux
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = constrain(x @ head, ("batch", "seq", "act_vocab"))
    return logits, aux


# ------------------------------------------------------------------ decode

def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int,
                      img_embeds=None, params=None) -> Dict[str, Any]:
    """Decode cache pytree (zeros; prefill fills it)."""
    state: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    L = cfg.n_layers
    KV, hd = cfg.n_kv_heads, cfg.hd
    if cfg.family in ("dense", "audio", "vlm", "moe"):
        state["k"] = jnp.zeros((L, batch, max_seq, KV, hd), jnp.bfloat16)
        state["v"] = jnp.zeros((L, batch, max_seq, KV, hd), jnp.bfloat16)
    if cfg.family in ("ssm", "hybrid"):
        conv, ssd_st = init_ssm_state(cfg, batch)
        state["conv"] = jnp.broadcast_to(conv[None], (L,) + conv.shape)
        state["ssd"] = jnp.broadcast_to(ssd_st[None], (L,) + ssd_st.shape)
    if cfg.family == "hybrid":
        n_inv = (L + cfg.shared_attn_every - 1) // cfg.shared_attn_every
        state["shared_k"] = jnp.zeros((n_inv, batch, max_seq, KV, hd),
                                      jnp.bfloat16)
        state["shared_v"] = jnp.zeros((n_inv, batch, max_seq, KV, hd),
                                      jnp.bfloat16)
    if cfg.family == "vlm":
        if params is not None and img_embeds is not None:
            state["img_kv"] = _image_kv(params["cross"], cfg, img_embeds)
        else:
            n_ca = L // cfg.cross_attn_every
            z = jnp.zeros((n_ca, batch, cfg.n_image_tokens, KV, hd),
                          jnp.bfloat16)
            state["img_kv"] = (z, z)
    return state


def decode_step(params, cfg: ModelConfig, state, tokens=None, *,
                inputs_embeds=None,
                unroll: int = 1) -> Tuple[jax.Array, Dict[str, Any]]:
    """One token for every sequence in the batch.  tokens: (B, 1)."""
    if inputs_embeds is not None:
        x = inputs_embeds.astype(jnp.bfloat16)
    else:
        x = params["embed"][tokens]
    B = x.shape[0]
    pos = state["pos"]
    positions = jnp.full((1,), pos, jnp.int32)

    if cfg.family in ("dense", "audio", "vlm", "moe"):
        def body(carry, scanned):
            x = carry
            lp, idx, kc, vc = (scanned["lp"], scanned["idx"], scanned["k"],
                               scanned["v"])
            out, (kc, vc) = attention(x, lp, cfg, positions=positions,
                                      cache=(kc, vc), cache_len=pos)
            x = x + out
            if cfg.family == "moe":
                ffn, _ = moe_ffn(x, lp, cfg)
            else:
                ffn = swiglu(x, lp, cfg)
            x = x + ffn
            if cfg.family == "vlm":
                def with_ca(x):
                    ca_idx = idx // cfg.cross_attn_every
                    cap = jax.tree.map(
                        lambda a: lax.dynamic_index_in_dim(a, ca_idx, 0, False),
                        params["cross"])
                    kv = jax.tree.map(
                        lambda a: lax.dynamic_index_in_dim(a, ca_idx, 0, False),
                        state["img_kv"])
                    return x + _cross_attention(x, cap, cfg, kv)
                x = lax.cond(idx % cfg.cross_attn_every == 0, with_ca,
                             lambda x: x, x)
            return x, {"k": kc, "v": vc}

        scanned = {"lp": params["blocks"], "idx": jnp.arange(cfg.n_layers),
                   "k": state["k"], "v": state["v"]}
        x, caches = lax.scan(body, x, scanned, unroll=unroll)
        new_state = dict(state, pos=pos + 1, k=caches["k"], v=caches["v"])
    else:
        def body(carry, scanned):
            x, shared_kv = carry
            lp, idx = scanned["lp"], scanned["idx"]
            x, (conv, ssd_st) = mamba_block(
                x, lp, cfg, state=(scanned["conv"], scanned["ssd"]))
            if cfg.family == "hybrid":
                def with_shared(ops):
                    x, (sk, sv) = ops
                    inv = idx // cfg.shared_attn_every
                    kc = lax.dynamic_index_in_dim(sk, inv, 0, False)
                    vc = lax.dynamic_index_in_dim(sv, inv, 0, False)
                    x, (kc, vc) = _shared_block(x, params["shared"], cfg,
                                                positions, cache=(kc, vc),
                                                cache_len=pos)
                    sk = lax.dynamic_update_index_in_dim(sk, kc, inv, 0)
                    sv = lax.dynamic_update_index_in_dim(sv, vc, inv, 0)
                    return x, (sk, sv)
                x, shared_kv = lax.cond(
                    idx % cfg.shared_attn_every == 0, with_shared,
                    lambda ops: ops, (x, shared_kv))
            return (x, shared_kv), {"conv": conv, "ssd": ssd_st}

        shared_kv = ((state["shared_k"], state["shared_v"])
                     if cfg.family == "hybrid" else ())
        scanned = {"lp": params["blocks"], "idx": jnp.arange(cfg.n_layers),
                   "conv": state["conv"], "ssd": state["ssd"]}
        (x, shared_kv), caches = lax.scan(body, (x, shared_kv), scanned,
                                          unroll=unroll)
        new_state = dict(state, pos=pos + 1, conv=caches["conv"],
                         ssd=caches["ssd"])
        if cfg.family == "hybrid":
            new_state["shared_k"], new_state["shared_v"] = shared_kv

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head
    return logits, new_state


# ------------------------------------------------------------------- loss

def lm_loss(logits, labels, aux=None, aux_weight: float = 0.01):
    """Token cross-entropy (f32) + optional MoE aux loss."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = (logz - gold).mean()
    if aux is not None:
        loss = loss + aux_weight * aux / 1.0
    return loss


def lm_loss_chunked(x, params, cfg: ModelConfig, labels, aux=None,
                    aux_weight: float = 0.01, vocab_chunk: int = 16384):
    """Memory-efficient cross-entropy: streams the vocab in chunks so the
    (B, S, V) f32 logits tensor never materializes (peak activation memory
    O(B·S·chunk) instead of O(B·S·V); the backward pass recomputes each
    chunk — classic remat-CE).  §Perf iteration for big-vocab train cells."""
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    V = head.shape[-1]
    nb = -(-V // vocab_chunk)
    pad = nb * vocab_chunk - V
    if pad:
        head = jnp.pad(head, ((0, 0), (0, pad)))
    B, S, d = x.shape

    def body(carry, i):
        m, s, gold = carry
        hc = lax.dynamic_slice_in_dim(head, i * vocab_chunk, vocab_chunk, 1)
        lg = (x @ hc).astype(jnp.float32)            # (B, S, chunk)
        base = i * vocab_chunk
        k_pos = base + jnp.arange(vocab_chunk)
        valid = k_pos < V
        lg = jnp.where(valid[None, None, :], lg, -1e30)
        m_new = jnp.maximum(m, lg.max(-1))
        s = s * jnp.exp(m - m_new) + jnp.exp(lg - m_new[..., None]).sum(-1)
        off = jnp.clip(labels - base, 0, vocab_chunk - 1)
        g = jnp.take_along_axis(lg, off[..., None], axis=-1)[..., 0]
        in_chunk = (labels >= base) & (labels < base + vocab_chunk)
        gold = jnp.where(in_chunk, g, gold)
        return (m_new, s, gold), None

    m0 = jnp.full((B, S), -1e30, jnp.float32)
    s0 = jnp.zeros((B, S), jnp.float32)
    g0 = jnp.zeros((B, S), jnp.float32)
    (m, s, gold), _ = lax.scan(jax.checkpoint(body), (m0, s0, g0),
                               jnp.arange(nb))
    loss = (jnp.log(s) + m - gold).mean()
    if aux is not None:
        loss = loss + aux_weight * aux
    return loss

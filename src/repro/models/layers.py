"""Shared layer library: RoPE, attention block, SwiGLU MLP, embeddings.

All activations keep logical sharding via ``with_sharding_constraint`` hints
applied in the model (not here) — layers are sharding-agnostic math.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import decode_attention, flash_attention, rmsnorm
from .config import ModelConfig
from .params import p


def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    if angles.ndim == 2:
        angles = angles[None]                           # (1, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- specs

def attention_specs(cfg: ModelConfig, layers: int, prefix_axes=("layers",)):
    """Stacked attention params for ``layers`` layers."""
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    L = (layers,)
    la = prefix_axes
    specs = {
        "attn_norm": p(L + (d,), la + ("norm",), init="ones"),
        "wq": p(L + (d, H * hd), la + ("embed", "heads")),
        "wk": p(L + (d, KV * hd), la + ("embed", "kv_heads")),
        "wv": p(L + (d, KV * hd), la + ("embed", "kv_heads")),
        "wo": p(L + (H * hd, d), la + ("heads", "embed")),
    }
    if cfg.qkv_bias:
        specs["bq"] = p(L + (H * hd,), la + ("heads",), init="zeros")
        specs["bk"] = p(L + (KV * hd,), la + ("kv_heads",), init="zeros")
        specs["bv"] = p(L + (KV * hd,), la + ("kv_heads",), init="zeros")
    if cfg.qk_norm:
        specs["q_norm"] = p(L + (hd,), la + ("norm",), init="ones")
        specs["k_norm"] = p(L + (hd,), la + ("norm",), init="ones")
    return specs


def mlp_specs(cfg: ModelConfig, layers: int, prefix_axes=("layers",)):
    d, f = cfg.d_model, cfg.d_ff
    L, la = (layers,), prefix_axes
    return {
        "ffn_norm": p(L + (d,), la + ("norm",), init="ones"),
        "w_gate": p(L + (d, f), la + ("embed", "ffn")),
        "w_up": p(L + (d, f), la + ("embed", "ffn")),
        "w_down": p(L + (f, d), la + ("ffn", "embed")),
    }


# ----------------------------------------------------------------- compute

def attention(x, lp, cfg: ModelConfig, *, positions, cache=None,
              cache_len=None, norm_eps=1e-5):
    """Pre-norm attention sublayer.

    Train/prefill: ``cache is None`` → causal flash attention.
    Decode: ``cache = (k_cache, v_cache)`` (B, S_max, KV, hd); new k/v are
    written at position ``cache_len`` and attention runs over the prefix.
    Returns (residual output, new_cache_or_None).
    """
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q = q + lp["bq"]
        k = k + lp["bk"]
        v = v + lp["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, lp["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, lp["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    new_cache = None
    if cache is None:
        attn = flash_attention(q, k, v, causal=True)
    else:
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), cache_len, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), cache_len, axis=1)
        attn = decode_attention(q, k_cache, v_cache, cache_len + S)
        new_cache = (k_cache, v_cache)
    out = attn.reshape(B, S, H * hd) @ lp["wo"]
    return out, new_cache


def swiglu(x, lp, cfg: ModelConfig):
    h = rmsnorm(x, lp["ffn_norm"], cfg.norm_eps)
    g = jax.nn.silu((h @ lp["w_gate"]).astype(jnp.float32)).astype(h.dtype)
    return (g * (h @ lp["w_up"])) @ lp["w_down"]

"""Logical-axis → mesh-axis sharding rules (MaxText-style).

Params and activations carry *logical* axis names ("embed", "heads", "ffn",
"experts", "batch", "seq", …); a rules table maps each to zero or more mesh
axes.  Hillclimbing a sharding scheme = editing one table (see §Perf in
EXPERIMENTS.md for the iterations).

Divisibility fallback: if a dimension is not divisible by the mapped mesh
axes' product (e.g. 4 KV heads on a 16-way model axis), the mapping is
dropped for that dim (replicated) rather than failing — recorded so the
roofline can report it.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.params import ParamSpec, is_spec

AxisMap = Union[str, Tuple[str, ...], None]
LogicalRules = Dict[str, AxisMap]

# The production mesh axes: ("pod",) "data", "model".
#   pod+data — DP/FSDP; model — TP/EP.
DEFAULT_RULES: LogicalRules = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_kv": None,
    "act_ffn": "model",
    "act_experts": "model",
    "act_vocab": "model",
    "moe_capacity": None,     # variant ep_capacity → "data"
    "cache_seq": None,
    "cache_batch": ("pod", "data"),
    # params: TP axis
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "vocab": "model",
    "embed_vocab": "model",   # the embedding table's vocab dim (gather side)
    # params: FSDP axis (the non-TP big dim of each matrix)
    "embed": "data",
    "embed_noshard": None,
    # stacked-layer dim and small vectors
    "layers": None,
    "norm": None,
    "conv": None,
    "ssm_state": None,
    "ssm_heads": "model",
    "ssm_inner": "model",
}


def _mesh_axes_size(mesh: Mesh, amap: AxisMap) -> int:
    if amap is None:
        return 1
    axes = (amap,) if isinstance(amap, str) else amap
    size = 1
    for a in axes:
        size *= mesh.shape.get(a, 1)
    return size


def _present(mesh: Mesh, amap: AxisMap) -> AxisMap:
    """Drop mesh axes that don't exist in this mesh (e.g. 'pod' single-pod)."""
    if amap is None:
        return None
    axes = (amap,) if isinstance(amap, str) else tuple(amap)
    axes = tuple(a for a in axes if a in mesh.shape)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def apply_rules(axes: Sequence[Optional[str]], shape: Sequence[int],
                mesh: Mesh, rules: Optional[LogicalRules] = None,
                used_ok: bool = False) -> P:
    """Logical axes of one array → PartitionSpec, with divisibility/duplicate
    fallback (an axis may shard at most one dim)."""
    rules = rules or DEFAULT_RULES
    spec = []
    used: set = set()
    for dim, name in zip(shape, axes):
        amap = _present(mesh, rules.get(name)) if name else None
        if amap is not None:
            flat = (amap,) if isinstance(amap, str) else tuple(amap)
            if any(a in used for a in flat) or dim % _mesh_axes_size(mesh, flat) != 0:
                amap = None
            else:
                used.update(flat)
        spec.append(amap)
    return P(*spec)


def logical_sharding(axes: Sequence[Optional[str]], shape: Sequence[int],
                     mesh: Mesh,
                     rules: Optional[LogicalRules] = None) -> NamedSharding:
    return NamedSharding(mesh, apply_rules(axes, shape, mesh, rules))


def shardings_for(specs, mesh: Mesh, rules: Optional[LogicalRules] = None):
    """Pytree of ParamSpec → pytree of NamedSharding."""
    return jax.tree.map(
        lambda s: logical_sharding(s.axes, s.shape, mesh, rules), specs,
        is_leaf=is_spec)

"""Ambient activation-sharding context.

Model code calls ``constrain(x, ("batch", "seq", "act_embed"))``; when a
(mesh, rules) context is active (set by the train/serve step builders), this
lowers to ``with_sharding_constraint`` with the logical rules applied —
otherwise it is a no-op (CPU smoke tests, plain eager use).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Sequence, Tuple

import jax

from .rules import LogicalRules, apply_rules

_CTX: contextvars.ContextVar = contextvars.ContextVar("shard_ctx",
                                                      default=None)


@contextlib.contextmanager
def sharding_ctx(mesh, rules: Optional[LogicalRules] = None):
    token = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(token)


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = apply_rules(names, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))

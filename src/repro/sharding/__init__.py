from .context import constrain, sharding_ctx
from .rules import (LogicalRules, DEFAULT_RULES, apply_rules, logical_sharding,
                    shardings_for)

__all__ = ["DEFAULT_RULES", "LogicalRules", "apply_rules", "constrain",
           "logical_sharding", "sharding_ctx", "shardings_for"]

"""Training-input pipeline that reads THROUGH IGTCache.

This is the production integration of the paper's technique: every byte a
training/eval job consumes is requested from the unified cache
(``IGTCache.read``), which observes the access stream, classifies it
(random for training epochs, sequential for eval sweeps) and adapts
prefetch/eviction/allocation accordingly.  No code intrusion above this
boundary — swap the loader's engine for a baseline bundle and the model code
never knows.

Token shards live in the (simulated) remote object store as big files;
sample i of a shard maps to a fixed byte range, so the cache sees the same
block-granular traffic a JuiceFS mount would.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from ..core.sharded import Engine
from ..core.types import MB, PathT
from ..storage.datasets import DatasetSpec, make_dataset
from ..storage.object_store import RemoteStore

# The pipeline only touches the engine's public read/prefetch surface, so
# the path-hash sharded facade (multiple token datasets spread over shards)
# drops in wherever the single state machine did.


def make_token_dataset(name: str, n_shards: int, shard_bytes: int) -> DatasetSpec:
    return make_dataset(name, "big_files", n_files=n_shards,
                        file_size=shard_bytes)


class PrefetchWorker(threading.Thread):
    """Background fetcher: engine candidates → store → complete_prefetch."""

    def __init__(self, engine: Engine, store: RemoteStore) -> None:
        super().__init__(daemon=True)
        self.engine = engine
        self.store = store
        self.q: "queue.Queue" = queue.Queue(maxsize=4096)
        self._stop = threading.Event()
        self.fetched = 0

    def submit(self, candidates) -> None:
        for cand in candidates:
            try:
                self.q.put_nowait(cand)
            except queue.Full:
                self.engine.cancel_prefetch(cand[0])

    def run(self) -> None:
        while not self._stop.is_set():
            try:
                path, size = self.q.get(timeout=0.1)
            except queue.Empty:
                continue
            # the actual byte movement (synthesized content, real code path)
            self.store.fetch_block(path, min(size, 4096))
            self.engine.complete_prefetch(path, size, time.monotonic())
            self.fetched += 1

    def stop(self) -> None:
        self._stop.set()


@dataclass
class PipelineStats:
    batches: int = 0
    bytes_read: int = 0
    cache_hits: int = 0
    cache_misses: int = 0

    @property
    def hit_ratio(self) -> float:
        n = self.cache_hits + self.cache_misses
        return self.cache_hits / n if n else 0.0


class CachedTokenPipeline:
    """Epoch-random LM batches served through the unified cache."""

    def __init__(self, store: RemoteStore, engine: Engine, dataset: str,
                 *, seq_len: int, batch: int, vocab: int, seed: int = 0,
                 sample_bytes: Optional[int] = None,
                 background_prefetch: bool = True,
                 access_pattern: str = "random") -> None:
        self.store = store
        self.engine = engine
        self.dataset = store.datasets[dataset]
        self.seq_len = seq_len
        self.batch = batch
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        self.sample_bytes = sample_bytes or (seq_len + 1) * 4
        self.access_pattern = access_pattern
        self.stats = PipelineStats()
        self._samples = []
        for f in self.dataset.files:
            n = f.size // self.sample_bytes
            for i in range(n):
                self._samples.append((f.path, i * self.sample_bytes))
        self.worker = PrefetchWorker(engine, store) if background_prefetch \
            else None
        if self.worker:
            self.worker.start()

    def _account_outcome(self, out, now: float) -> None:
        self.stats.cache_hits += sum(1 for b in out.blocks if b.hit)
        self.stats.cache_misses += sum(1 for b in out.blocks if not b.hit)
        self.stats.bytes_read += self.sample_bytes
        if self.worker:
            self.worker.submit(out.prefetches)
        else:
            for path, size in out.prefetches:
                self.engine.complete_prefetch(path, size, now)

    def _synth_tokens(self, fpath: PathT, offset: int) -> np.ndarray:
        # deterministic synthetic tokens for the sample's byte range
        block = offset // (4 * MB)
        raw = self.store.fetch_block(fpath + (f"#{block}",),
                                     self.sample_bytes)
        tokens = raw.astype(np.int64)
        tokens = (tokens[0::4] * 16777619 + tokens[1::4] * 65537
                  + tokens[2::4] * 257 + tokens[3::4]) % self.vocab
        return tokens[: self.seq_len + 1].astype(np.int32)

    def _read_sample(self, fpath: PathT, offset: int) -> np.ndarray:
        now = time.monotonic()
        out = self.engine.read(fpath, offset, self.sample_bytes, now)
        self._account_outcome(out, now)
        return self._synth_tokens(fpath, offset)

    def batches(self, epochs: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        order = np.arange(len(self._samples))
        for _ in range(epochs):
            if self.access_pattern == "random":
                self.rng.shuffle(order)
            for i in range(0, len(order) - self.batch + 1, self.batch):
                group = [self._samples[j] for j in order[i:i + self.batch]]
                now = time.monotonic()
                # batched read path: the whole training batch goes through
                # the engine in one call (tick cadence amortized per batch)
                outs = self.engine.read_batch(
                    [(fp, off, self.sample_bytes) for fp, off in group], now)
                for out in outs:
                    self._account_outcome(out, now)
                toks = [self._synth_tokens(fp, off) for fp, off in group]
                arr = np.stack(toks)
                self.stats.batches += 1
                yield {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def close(self) -> None:
        if self.worker:
            self.worker.stop()

"""Training-input pipeline that reads THROUGH the unified cache client.

This is the production integration of the paper's technique: every byte a
training/eval job consumes is requested from the unified cache via the
``CacheClient`` API, whose kernel observes the access stream, classifies
it (random for training epochs, sequential for eval sweeps) and adapts
prefetch/eviction/allocation accordingly.  No code intrusion above this
boundary — swap the client's engine for a baseline bundle and the model
code never knows.

Prefetch transport is the client's executor: the per-shard
``ThreadedExecutor`` for real training runs (background workers fetch
candidate bytes and complete them on the kernel; overflow/shutdown
*cancels* candidates instead of dropping them, and both outcomes are
visible in :class:`PipelineStats`), or the deterministic inline
``SimExecutor`` when ``background_prefetch=False`` (tests, virtual-clock
callers).

Token shards live in the (simulated) remote object store as big files;
sample i of a shard maps to a fixed byte range, so the cache sees the same
block-granular traffic a JuiceFS mount would.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Union

import numpy as np

from ..core.client import CacheClient, SimExecutor, ThreadedExecutor
from ..core.sharded import Engine
from ..core.types import MB, PathT, block_key
from ..storage.datasets import DatasetSpec, make_dataset
from ..storage.object_store import RemoteStore


def make_token_dataset(name: str, n_shards: int, shard_bytes: int) -> DatasetSpec:
    return make_dataset(name, "big_files", n_files=n_shards,
                        file_size=shard_bytes)


@dataclass
class PipelineStats:
    batches: int = 0
    bytes_read: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    # executor-side candidate accounting (the old PrefetchWorker lost
    # overflow cancels silently; now every candidate is either completed
    # or cancelled, and both show up here)
    prefetch_submitted: int = 0
    prefetch_completed: int = 0
    prefetch_cancelled: int = 0

    @property
    def hit_ratio(self) -> float:
        n = self.cache_hits + self.cache_misses
        return self.cache_hits / n if n else 0.0


class CachedTokenPipeline:
    """Epoch-random LM batches served through the unified cache client."""

    def __init__(self, store: RemoteStore,
                 engine: Union[Engine, CacheClient], dataset: str,
                 *, seq_len: int, batch: int, vocab: int, seed: int = 0,
                 sample_bytes: Optional[int] = None,
                 background_prefetch: bool = True,
                 prefetch_queue_depth: int = 4096,
                 access_pattern: str = "random") -> None:
        self.store = store
        if isinstance(engine, CacheClient):
            self.client = engine
            self._own_client = False
        else:
            # one constructor path: candidates ride per-shard worker
            # threads (wall clock) or complete inline at the read's own
            # timestamp (deterministic, matches the caller-driven loop)
            executor = (ThreadedExecutor(queue_depth=prefetch_queue_depth)
                        if background_prefetch else SimExecutor())
            self.client = CacheClient(engine, backing=store,
                                      executor=executor)
            self._own_client = True
        self.engine = self.client.engine
        # per-pipeline attribution on a possibly shared client: report
        # executor counters as deltas from this construction point
        ex = self.client.executor.stats
        self._ex_base = (ex.submitted, ex.completed, ex.cancelled)
        self.dataset = store.datasets[dataset]
        self.seq_len = seq_len
        self.batch = batch
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        self.sample_bytes = sample_bytes or (seq_len + 1) * 4
        self.access_pattern = access_pattern
        self.stats = PipelineStats()
        self._samples = []
        for f in self.dataset.files:
            n = f.size // self.sample_bytes
            for i in range(n):
                self._samples.append((f.path, i * self.sample_bytes))

    def _account_outcome(self, out) -> None:
        self.stats.cache_hits += sum(1 for b in out.blocks if b.hit)
        self.stats.cache_misses += sum(1 for b in out.blocks if not b.hit)
        self.stats.bytes_read += self.sample_bytes
        self._sync_prefetch_stats()

    def _sync_prefetch_stats(self) -> None:
        ex = self.client.executor.stats
        base = self._ex_base
        self.stats.prefetch_submitted = ex.submitted - base[0]
        self.stats.prefetch_completed = ex.completed - base[1]
        self.stats.prefetch_cancelled = ex.cancelled - base[2]

    def _synth_tokens(self, fpath: PathT, offset: int) -> np.ndarray:
        # deterministic synthetic tokens for the sample's byte range
        block = offset // (4 * MB)
        raw = self.store.fetch_block(block_key(fpath, block),
                                     self.sample_bytes)
        tokens = raw.astype(np.int64)
        tokens = (tokens[0::4] * 16777619 + tokens[1::4] * 65537
                  + tokens[2::4] * 257 + tokens[3::4]) % self.vocab
        return tokens[: self.seq_len + 1].astype(np.int32)

    def _read_sample(self, fpath: PathT, offset: int) -> np.ndarray:
        res = self.client.read(fpath, offset, self.sample_bytes,
                               time.monotonic())
        self._account_outcome(res.outcome)
        return self._synth_tokens(fpath, offset)

    def batches(self, epochs: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        order = np.arange(len(self._samples))
        for _ in range(epochs):
            if self.access_pattern == "random":
                self.rng.shuffle(order)
            for i in range(0, len(order) - self.batch + 1, self.batch):
                group = [self._samples[j] for j in order[i:i + self.batch]]
                now = time.monotonic()
                # batched client path: the whole training batch goes
                # through the kernel in one call (tick cadence amortized
                # per batch); prefetch dispatch is the executor's job
                results = self.client.read_batch(
                    [(fp, off, self.sample_bytes) for fp, off in group], now)
                for res in results:
                    self._account_outcome(res.outcome)
                toks = [self._synth_tokens(fp, off) for fp, off in group]
                arr = np.stack(toks)
                self.stats.batches += 1
                yield {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Wait for in-flight background prefetches to land (tests /
        deterministic epoch boundaries)."""
        return self.client.flush(timeout)

    def close(self) -> None:
        if self._own_client:
            self.client.close()
        self._sync_prefetch_stats()

"""Train step: loss → grad → AdamW, with microbatch gradient accumulation,
per-layer remat, and logical-rule sharding on params / optimizer state /
batch.  The returned step is a plain jit-able function; ``lower_train_step``
gives the dry-run entry point (AOT lower + compile on abstract inputs).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig, ShapeSpec
from ..models.transformer import (abstract_params, build_specs, forward,
                                  lm_loss, lm_loss_chunked)
from ..sharding import (DEFAULT_RULES, LogicalRules, apply_rules,
                        logical_sharding, sharding_ctx, shardings_for)
from .optimizer import AdamWConfig, AdamWState, abstract_state, apply_updates


def batch_structs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStructs for one global training batch."""
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.family == "audio":
        # frontend stub: precomputed EnCodec frame embeddings
        batch["inputs_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                      jnp.bfloat16)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    return batch


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                    rules: Optional[LogicalRules] = None):
    structs = batch_structs(cfg, shape)
    names = {
        "labels": ("batch", "seq"),
        "tokens": ("batch", "seq"),
        "inputs_embeds": ("batch", "seq", "act_embed"),
        "img_embeds": ("batch", "seq", "act_embed"),
    }
    return {k: logical_sharding(names[k], v.shape, mesh, rules)
            for k, v in structs.items()}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh: Mesh,
                    rules: Optional[LogicalRules] = None, *,
                    remat: str = "full", microbatches: int = 1,
                    unroll: int = 1, loss_impl: str = "dense"):
    """Returns ``train_step(params, opt_state, batch) -> (params, opt_state,
    metrics)`` with sharding applied inside via the ambient context.
    ``loss_impl='chunked'`` streams the vocab in the CE (memory-efficient)."""

    def loss_fn(params, batch):
        with sharding_ctx(mesh, rules):
            out, aux = forward(
                params, cfg,
                batch.get("tokens"),
                inputs_embeds=batch.get("inputs_embeds"),
                img_embeds=batch.get("img_embeds"),
                remat=remat, unroll=unroll,
                return_hidden=(loss_impl == "chunked"))
            maux = aux if cfg.family == "moe" else None
            if loss_impl == "chunked":
                return lm_loss_chunked(out, params, cfg, batch["labels"],
                                       maux)
            return lm_loss(out, batch["labels"], maux)

    def train_step(params, opt_state: AdamWState, batch):
        if microbatches > 1:
            def micro(g_acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return jax.tree.map(jnp.add, g_acc,
                                    jax.tree.map(
                                        lambda x: x.astype(jnp.float32) /
                                        microbatches, g)), l
            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches) + x.shape[1:]),
                batch)
            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                              params)
            grads, losses = jax.lax.scan(micro, g0, mbs)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        with sharding_ctx(mesh, rules):
            params, opt_state, metrics = apply_updates(params, grads,
                                                       opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def lower_train_step(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                     rules: Optional[LogicalRules] = None, *,
                     remat: str = "full", microbatches: int = 1,
                     opt_cfg: Optional[AdamWConfig] = None, unroll: int = 1,
                     loss_impl: str = "dense"):
    """AOT-lower the train step on abstract inputs (the dry-run entry)."""
    opt_cfg = opt_cfg or AdamWConfig()
    specs = build_specs(cfg)
    params_s = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "init"))
    params_sh = shardings_for(specs, mesh, rules)
    opt_s = abstract_state(params_s)
    opt_sh = AdamWState(
        NamedSharding(mesh, P()),
        jax.tree.map(lambda s: s, params_sh), params_sh)
    batch_s = batch_structs(cfg, shape)
    batch_sh = batch_shardings(cfg, shape, mesh, rules)

    step = make_train_step(cfg, opt_cfg, mesh, rules, remat=remat,
                           microbatches=microbatches, unroll=unroll,
                           loss_impl=loss_impl)
    jitted = jax.jit(
        step,
        in_shardings=(params_sh, opt_sh, batch_sh),
        out_shardings=(params_sh, opt_sh, None),
        donate_argnums=(0, 1))
    return jitted.lower(params_s, opt_s, batch_s)

"""AdamW implemented in-repo (no optax dependency).

Optimizer state shards identically to the params (the ShapeDtypeStructs /
NamedShardings are derived from the param tree), so FSDP covers moments too.
Includes global-norm clipping and a linear-warmup cosine schedule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # gradient compression applied before the (implicit) cross-replica
    # reduction: "none" | "int8" (per-tensor absmax scale).  int8 quarters
    # the gradient reduce-scatter payload at <0.4 % relative error; on a
    # shard_map runtime the quantize lives inside the custom all-reduce —
    # here it wraps the grads so the lowered collective moves int8.
    grad_compression: str = "none"


def compress_grads(grads, method: str):
    """Quantize→dequantize gradients (simulating a compressed all-reduce)."""
    if method == "none":
        return grads

    def q(g):
        gf = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        qi = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        return qi.astype(jnp.float32) * scale

    if method == "int8":
        return jax.tree.map(q, grads)
    raise ValueError(f"unknown grad_compression {method!r}")


class AdamWState(NamedTuple):
    step: jax.Array          # i32 scalar
    mu: Any                  # first moment (f32, like params)
    nu: Any                  # second moment (f32)


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def abstract_state(param_structs) -> AdamWState:
    z = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32),
                     param_structs)
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32), z, z)


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip((s - cfg.warmup_steps) /
                    jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.minimum(warm, decayed)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, state: AdamWState,
                  cfg: AdamWConfig) -> Tuple[Any, AdamWState, dict]:
    step = state.step + 1
    grads = compress_grads(grads, cfg.grad_compression)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics

"""Sharded checkpointing with atomic commit, async save and ELASTIC restore.

Layout:
    <dir>/step_<N>/
        manifest.json       — tree structure, shapes, dtypes, mesh, rules ver
        shard_<i>.npz       — one file per host (here: per save worker)
    <dir>/LATEST            — atomic pointer (rename commit)

Fault-tolerance properties:
  * atomic: a crash mid-save never corrupts LATEST (tmp dir + rename);
  * async: `save_async` snapshots device arrays then writes on a thread —
    the train loop is blocked only for the device→host copy;
  * elastic: `restore` reshards to ANY mesh/host count — arrays are stored
    unsharded (host-gathered) at this scale; restore applies the target
    NamedShardings (for >1k-node scale, swap the .npz writer for per-shard
    files keyed by shard index — the manifest schema already carries the
    PartitionSpec strings needed to reassemble).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> Path:
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)
        return self._write(step, host_tree, extra)

    def save_async(self, step: int, tree: Any,
                   extra: Optional[dict] = None) -> None:
        """Snapshot to host, then write on a background thread."""
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # device→host copy
        t = threading.Thread(target=self._write, args=(step, host_tree, extra),
                             daemon=True)
        t.start()
        self._pending = t

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_tree: Any, extra: Optional[dict]) -> Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves = _flatten_with_paths(host_tree)
        manifest = {
            "step": step,
            "time": time.time(),
            "extra": extra or {},
            "leaves": [{"key": k, "shape": list(np.shape(v)),
                        "dtype": str(np.asarray(v).dtype)}
                       for k, v in leaves],
        }
        def storable(v):
            a = np.asarray(v)
            # npz can't round-trip ml_dtypes (bf16/fp8 have kind 'V') —
            # store as f32 (lossless upcast); restore casts back.
            if a.dtype.kind == "V":
                a = a.astype(np.float32)
            return a

        np.savez(tmp / "shard_0.npz",
                 **{f"leaf_{i}": storable(v)
                    for i, (k, v) in enumerate(leaves)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                            # atomic commit
        latest_tmp = self.dir / ".LATEST.tmp"
        latest_tmp.write_text(final.name)
        latest_tmp.rename(self.dir / "LATEST")
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(d for d in self.dir.iterdir()
                       if d.is_dir() and d.name.startswith("step_"))
        for d in steps[:-self.keep]:
            shutil.rmtree(d, ignore_errors=True)

    # ---------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        ptr = self.dir / "LATEST"
        if not ptr.exists():
            return None
        return int(ptr.read_text().strip().split("_")[-1])

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``tree_like``; if ``shardings`` is
        given (a matching pytree of NamedSharding), arrays are placed
        sharded — on any mesh, regardless of the mesh at save time."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "shard_0.npz")
        arrays = [data[f"leaf_{i}"] for i in range(len(manifest["leaves"]))]
        flat_like, treedef = jax.tree.flatten(tree_like)
        if len(flat_like) != len(arrays):
            raise ValueError(
                f"checkpoint has {len(arrays)} leaves, target structure has "
                f"{len(flat_like)} — incompatible trees")
        out = []
        flat_sh = (treedef.flatten_up_to(shardings)
                   if shardings is not None else [None] * len(arrays))
        for like, arr, sh in zip(flat_like, arrays, flat_sh):
            jarr = jax.numpy.asarray(arr)
            if hasattr(like, "dtype") and jarr.dtype != like.dtype:
                jarr = jarr.astype(like.dtype)   # jax handles bf16 casts
            if sh is not None:
                jarr = jax.device_put(jarr, sh)
            out.append(jarr)
        return treedef.unflatten(out), manifest["extra"]

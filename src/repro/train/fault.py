"""Fault tolerance & straggler mitigation for the training driver.

At 1000+-node scale the failure domains are: worker crash (restart from the
latest checkpoint), slow worker (straggler), and preemption (checkpoint on
signal).  On a single host we implement the full control flow against a
simulated failure injector so the logic is testable end-to-end:

  * ``Heartbeat`` — per-worker liveness with a deadline; missed deadline =
    failure → driver restores from the last committed checkpoint and
    reassigns the worker's data shard.
  * ``StragglerDetector`` — EWMA of per-worker step times; a worker slower
    than ``factor``× the median is flagged; mitigation = deterministic data
    re-sharding (the IGTCache layer makes the replacement warm: the dataset's
    blocks are already resident, so a restarted worker skips the cold-start
    misses).
  * ``PreemptionGuard`` — SIGTERM → synchronous checkpoint then exit.

The multi-controller JAX runtime handles collective-level failure detection;
this module is the *policy* layer above it.
"""
from __future__ import annotations

import signal
import threading
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set


@dataclass
class Heartbeat:
    """Per-worker liveness with a deadline.

    Thread-safe: the cache driver's supervisor polls ``dead_workers``
    from its own thread while per-channel receiver threads ``beat`` —
    the beat map is snapshotted under a lock so concurrent beats never
    race the scan (it is also the training driver's single-threaded
    liveness tracker, which the lock leaves untouched semantically).
    """

    deadline_s: float = 60.0
    last_beat: Dict[int, float] = field(default_factory=dict)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def beat(self, worker: int, now: Optional[float] = None) -> None:
        with self._lock:
            self.last_beat[worker] = now if now is not None else time.time()

    def dead_workers(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        with self._lock:
            items = list(self.last_beat.items())
        return [w for w, t in items if now - t > self.deadline_s]


class StragglerDetector:
    def __init__(self, factor: float = 1.8, alpha: float = 0.3) -> None:
        self.factor = factor
        self.alpha = alpha
        self.ewma: Dict[int, float] = {}

    def record(self, worker: int, step_time: float) -> None:
        prev = self.ewma.get(worker, step_time)
        self.ewma[worker] = (1 - self.alpha) * prev + self.alpha * step_time

    def stragglers(self) -> List[int]:
        if len(self.ewma) < 2:
            return []
        times = sorted(self.ewma.values())
        median = times[len(times) // 2]
        return [w for w, t in self.ewma.items() if t > self.factor * median]


def reassign_shards(n_shards: int, workers: Set[int]) -> Dict[int, List[int]]:
    """Deterministic shard→worker assignment for the surviving workers
    (stable under membership change: shard s → sorted_workers[s % n])."""
    ws = sorted(workers)
    out: Dict[int, List[int]] = {w: [] for w in ws}
    for s in range(n_shards):
        out[ws[s % len(ws)]].append(s)
    return out


class PreemptionGuard:
    """SIGTERM/SIGINT → run the checkpoint callback once, then re-raise."""

    def __init__(self, on_preempt: Callable[[], None]) -> None:
        self.on_preempt = on_preempt
        self.preempted = False
        self._old = {}

    def __enter__(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._old[sig] = signal.signal(sig, self._handler)
        return self

    def _handler(self, signum, frame):
        if not self.preempted:
            self.preempted = True
            self.on_preempt()
        raise KeyboardInterrupt

    def __exit__(self, *exc):
        for sig, old in self._old.items():
            signal.signal(sig, old)
        return False


@dataclass
class FailureInjector:
    """Deterministic failure schedule for tests: {step: [workers]}."""

    crash_at: Dict[int, List[int]] = field(default_factory=dict)
    slow_at: Dict[int, List[int]] = field(default_factory=dict)
    slow_factor: float = 3.0

    def crashed(self, step: int) -> List[int]:
        return self.crash_at.get(step, [])

    def step_time(self, worker: int, step: int, base: float) -> float:
        if worker in self.slow_at.get(step, []):
            return base * self.slow_factor
        return base

"""Pallas TPU fused RMSNorm kernel.

Fuses the mean-square reduction, rsqrt and scale in one VMEM pass (XLA often
splits these into separate HBM round-trips around the reduction).  Rows are
tiled ``block_rows`` at a time; the feature dim stays whole in VMEM
(d_model ≤ 16k → ≤ 64 KB/row at f32, fine).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x, weight, eps: float = 1e-5, block_rows: int = 256,
                   interpret: bool = False):
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    rows = x2.shape[0]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, weight)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)

from .ops import gated_rmsnorm, rmsnorm
from .kernel import rmsnorm_pallas
from .ref import gated_rmsnorm_ref, rmsnorm_ref

__all__ = ["gated_rmsnorm", "gated_rmsnorm_ref", "rmsnorm", "rmsnorm_pallas",
           "rmsnorm_ref"]

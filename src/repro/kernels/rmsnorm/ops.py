from __future__ import annotations

import jax

from .kernel import rmsnorm_pallas
from .ref import gated_rmsnorm_ref, rmsnorm_ref


def rmsnorm(x, weight, eps: float = 1e-5, force_ref: bool = False):
    if jax.default_backend() == "tpu" and not force_ref:
        return rmsnorm_pallas(x, weight, eps=eps)
    return rmsnorm_ref(x, weight, eps=eps)


def gated_rmsnorm(x, gate, weight, eps: float = 1e-5):
    return gated_rmsnorm_ref(x, gate, weight, eps=eps)

"""Pure-jnp RMSNorm oracle (f32 accumulation, bf16 in/out)."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_ref(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jnp.reciprocal(jnp.sqrt(var + eps))
    return (y * weight.astype(jnp.float32)).astype(x.dtype)


def gated_rmsnorm_ref(x, gate, weight, eps: float = 1e-5):
    """Mamba2's out-norm: rmsnorm(x * silu(gate)) variant (norm-then-gate)."""
    xf = x.astype(jnp.float32)
    g = gate.astype(jnp.float32)
    xf = xf * (g * jnp.reciprocal(1.0 + jnp.exp(-g)))
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jnp.reciprocal(jnp.sqrt(var + eps))
    return (y * weight.astype(jnp.float32)).astype(x.dtype)

from .ops import ssd, ssd_decode
from .kernel import ssd_chunk_pallas
from .ref import segsum, ssd_decode_ref, ssd_ref

__all__ = ["segsum", "ssd", "ssd_chunk_pallas", "ssd_decode",
           "ssd_decode_ref", "ssd_ref"]

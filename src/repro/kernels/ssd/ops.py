"""SSD dispatch: Pallas intra-chunk kernel + jnp inter-chunk recurrence on
TPU; full jnp oracle elsewhere."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import ssd_chunk_pallas
from .ref import segsum, ssd_decode_ref, ssd_ref


def ssd(x, a, B, C, chunk: int = 256, initial_state=None, force_ref=False):
    if jax.default_backend() != "tpu" or force_ref:
        return ssd_ref(x, a, B, C, chunk=chunk, initial_state=initial_state)
    b, s, h, p = x.shape
    n = B.shape[-1]
    if s % chunk:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = x.shape[1]
    c = sp // chunk
    xc = x.astype(jnp.float32).reshape(b, c, chunk, h, p)
    ac = a.astype(jnp.float32).reshape(b, c, chunk, h)
    Bc = B.astype(jnp.float32).reshape(b, c, chunk, n)
    Cc = C.astype(jnp.float32).reshape(b, c, chunk, n)
    y_diag, states = ssd_chunk_pallas(xc, ac, Bc, Cc)
    # inter-chunk recurrence (small) in jnp
    a_cum = jnp.cumsum(ac.transpose(0, 3, 1, 2), axis=-1)        # (b,h,c,l)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)
    states = jnp.concatenate([initial_state[:, None], states], axis=1)
    chunk_decay = a_cum[..., -1]
    dc = jnp.exp(segsum(jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))))
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", dc, states)
    carry, final = new_states[:, :-1], new_states[:, -1]
    out_decay = jnp.exp(a_cum)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, carry, out_decay)
    y = (y_diag + y_off).reshape(b, sp, h, p)[:, :s]
    return y.astype(x.dtype), final


def ssd_decode(x_t, a_t, B_t, C_t, state):
    return ssd_decode_ref(x_t, a_t, B_t, C_t, state)

"""Pallas TPU kernel for the SSD intra-chunk block (the compute hot spot).

For one (batch, chunk, head) the kernel fuses, entirely in VMEM:
    scores   = C Bᵀ ∘ exp(segsum(a))      (l × l masked decay matmul)
    y_diag   = scores @ x                 (l × p)
    state    = (B ∘ decay_to_end)ᵀ @ x    (n × p chunk output state)
avoiding three HBM round-trips of (l, l) intermediates.  The cross-chunk
recurrence (tiny (h, p, n) states) stays in jnp — it is latency-, not
bandwidth-bound.

VMEM at l=256, n=128, p=64: x 64 KB, B/C 128 KB each, scores 256 KB f32 —
comfortably within budget; all matmul dims are 64/128-aligned for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _ssd_chunk_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_ref):
    x = x_ref[0, 0, 0].astype(jnp.float32)        # (l, p)
    a = a_ref[0, 0, 0].astype(jnp.float32)        # (l,)
    B = b_ref[0, 0].astype(jnp.float32)           # (l, n)
    C = c_ref[0, 0].astype(jnp.float32)           # (l, n)
    l = x.shape[0]
    cum = jnp.cumsum(a)
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ()))) * L  # (l, l)
    y_ref[0, 0, 0] = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ()))).astype(y_ref.dtype)
    decay_end = jnp.exp(cum[-1] - cum)[:, None]   # (l, 1)
    st_ref[0, 0, 0] = jax.lax.dot_general(
        B * decay_end, x, (((0,), (0,)), ((), ()))).astype(st_ref.dtype)


def ssd_chunk_pallas(xc, ac, Bc, Cc, interpret: bool = False):
    """xc (b, c, l, h, p); ac (b, c, l, h); Bc/Cc (b, c, l, n)
    → (y_diag (b, c, l, h, p), states (b, c, h, n, p))."""
    b, c, l, h, p = xc.shape
    n = Bc.shape[-1]
    xt = xc.transpose(0, 1, 3, 2, 4)      # (b, c, h, l, p)
    at = ac.transpose(0, 1, 3, 2)         # (b, c, h, l)
    y, st = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(b, c, h),
        in_specs=[
            pl.BlockSpec((1, 1, 1, l, p), lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, l), lambda i, j, k: (i, j, k, 0)),
            pl.BlockSpec((1, 1, l, n), lambda i, j, k: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, l, n), lambda i, j, k: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, l, p), lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, n, p), lambda i, j, k: (i, j, k, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, c, h, l, p), jnp.float32),
            jax.ShapeDtypeStruct((b, c, h, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(xt, at, Bc, Cc)
    return y.transpose(0, 1, 3, 2, 4), st.transpose(0, 1, 2, 4, 3)

"""Pure-jnp Mamba2 SSD (state-space duality) oracle — chunked algorithm.

Follows the minimal SSD formulation (Dao & Gu, arXiv:2405.21060): the
sequence is split into chunks; within a chunk the recurrence is the masked
quadratic form (C Bᵀ ∘ L) X (matmul-friendly, the "duality"), across chunks a
small state recurrence carries (h, p, n) states.

Conventions: x (b, s, h, p) pre-multiplied by dt; a (b, s, h) = dt * A_log
(negative); B, C (b, s, n) single group shared across heads.
Returns (y, final_state (b, h, p, n)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def segsum(x):
    """x (..., l) → (..., l, l): S[i, j] = sum_{k in (j, i]} x[k], -inf for j>i."""
    l = x.shape[-1]
    cum = jnp.cumsum(x, axis=-1)
    d = cum[..., :, None] - cum[..., None, :]
    i = jnp.arange(l)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, d, NEG_INF)


def ssd_ref(x, a, B, C, chunk: int = 256, initial_state=None):
    b, s, h, p = x.shape
    n = B.shape[-1]
    if s % chunk:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    sp = x.shape[1]
    c = sp // chunk

    xc = x.astype(jnp.float32).reshape(b, c, chunk, h, p)
    ac = a.astype(jnp.float32).reshape(b, c, chunk, h).transpose(0, 3, 1, 2)  # (b,h,c,l)
    Bc = B.astype(jnp.float32).reshape(b, c, chunk, n)
    Cc = C.astype(jnp.float32).reshape(b, c, chunk, n)

    a_cum = jnp.cumsum(ac, axis=-1)                       # (b,h,c,l)
    L = jnp.exp(segsum(ac))                               # (b,h,c,l,l)
    # intra-chunk
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xc)
    # chunk output states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)       # (b,h,c,l)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xc)
    # inter-chunk recurrence
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)
    states = jnp.concatenate([initial_state[:, None], states], axis=1)  # (b,c+1,h,p,n)
    chunk_decay = a_cum[..., -1]                          # (b,h,c)
    dc = jnp.exp(segsum(jnp.pad(chunk_decay, ((0, 0), (0, 0), (1, 0)))))  # (b,h,c+1,c+1)
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", dc, states)
    carry, final = new_states[:, :-1], new_states[:, -1]
    # inter-chunk contribution
    out_decay = jnp.exp(a_cum)                            # (b,h,c,l)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, carry, out_decay)
    y = (y_diag + y_off).reshape(b, sp, h, p)[:, :s]
    return y.astype(x.dtype), final


def ssd_decode_ref(x_t, a_t, B_t, C_t, state):
    """One decode step.  x_t (b, h, p) pre-multiplied by dt; a_t (b, h);
    B_t, C_t (b, n); state (b, h, p, n) → (y_t, new_state)."""
    decay = jnp.exp(a_t.astype(jnp.float32))[..., None, None]      # (b,h,1,1)
    upd = jnp.einsum("bhp,bn->bhpn", x_t.astype(jnp.float32),
                     B_t.astype(jnp.float32))
    new_state = state * decay + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_t.astype(jnp.float32))
    return y.astype(x_t.dtype), new_state

"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three layers: ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (dispatching jit-able wrapper) and ``ref.py`` (pure-jnp oracle).
CPU validation runs the kernels with interpret=True against the oracles
(tests/test_kernels.py sweeps shapes and dtypes).
"""
from .flash_attention import decode_attention, flash_attention
from .rmsnorm import gated_rmsnorm, rmsnorm
from .ssd import ssd, ssd_decode

__all__ = ["decode_attention", "flash_attention", "gated_rmsnorm", "rmsnorm",
           "ssd", "ssd_decode"]

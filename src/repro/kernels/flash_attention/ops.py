"""Dispatching wrapper: Pallas on TPU, jnp oracle elsewhere (CPU dry-run &
tests).  The two paths are numerically cross-checked in
tests/test_kernels.py (interpret=True)."""
from __future__ import annotations

import jax

from .kernel import flash_attention_pallas
from .ref import decode_attention_ref, flash_attention_ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def flash_attention(q, k, v, *, causal=True, q_offset=0, block_kv=1024,
                    softmax_scale=None, force_ref=False):
    if _on_tpu() and not force_ref:
        return flash_attention_pallas(q, k, v, causal=causal,
                                      q_offset=q_offset,
                                      softmax_scale=softmax_scale)
    return flash_attention_ref(q, k, v, causal=causal, q_offset=q_offset,
                               block_kv=block_kv, softmax_scale=softmax_scale)


def decode_attention(q, k, v, kv_len, softmax_scale=None):
    # Single-query attention is memory-bound; the einsum form lets XLA fuse
    # and shard it (incl. sequence-sharded caches) without a custom kernel.
    return decode_attention_ref(q, k, v, kv_len, softmax_scale=softmax_scale)

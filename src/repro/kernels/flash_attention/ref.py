"""Pure-jnp oracle for blockwise causal (flash) attention with GQA.

Exact online-softmax over KV blocks — the numerical reference for the Pallas
kernel AND the implementation lowered in CPU dry-runs (never materializes the
S×S score matrix; HLO stays compact via ``lax.scan`` over KV blocks).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        *, causal: bool = True, q_offset: int = 0,
                        block_kv: int = 1024,
                        softmax_scale: Optional[float] = None) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) with H % KV == 0.

    ``q_offset`` is the absolute position of q[0] (for chunked prefill).
    Returns (B, Sq, H, hd) in q.dtype; accumulation in f32.
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    assert H % KV == 0, (H, KV)
    groups = H // KV
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)

    block_kv = min(block_kv, Skv)
    if Skv % block_kv != 0:  # pad KV to a block multiple (masked out)
        pad = block_kv - Skv % block_kv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_valid = Skv
        Skv = Skv + pad
    else:
        kv_valid = Skv
    nb = Skv // block_kv

    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)  # (B,H,Sq,hd)
    kb = k.astype(jnp.float32).reshape(B, nb, block_kv, KV, hd)
    vb = v.astype(jnp.float32).reshape(B, nb, block_kv, KV, hd)
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, blk):
        o, m, l = carry
        kblk, vblk, j = blk                      # (B, block_kv, KV, hd), j
        # GQA: expand kv heads to H lazily via reshape of q side
        qg = qf.reshape(B, KV, groups, Sq, hd)
        s = jnp.einsum("bkgqd,bckd->bkgqc", qg, kblk)
        k_pos = j * block_kv + jnp.arange(block_kv)
        mask = k_pos[None, :] < kv_valid
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bkgqd", p, vblk)
        o_new = o * alpha[..., None] + pv
        return (o_new, m_new, l_new), None

    o0 = jnp.zeros((B, KV, groups, Sq, hd), jnp.float32)
    m0 = jnp.full((B, KV, groups, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, groups, Sq), jnp.float32)
    (o, m, l), _ = lax.scan(
        body, (o0, m0, l0),
        (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
         jnp.arange(nb)))
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array | int,
                         softmax_scale: Optional[float] = None) -> jax.Array:
    """Single-token decode attention over a (possibly padded) KV cache.

    q: (B, 1, H, hd); k, v: (B, S_max, KV, hd); ``kv_len`` = valid prefix
    length (scalar or (B,)).  Memory-bound: one pass, no blocking needed.
    """
    B, _, H, hd = q.shape
    _, S, KV, _ = k.shape
    groups = H // KV
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    qg = (q.astype(jnp.float32) * scale).reshape(B, KV, groups, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    pos = jnp.arange(S)
    valid = (pos[None, :] < jnp.asarray(kv_len).reshape(-1, 1)) if jnp.ndim(
        jnp.asarray(kv_len)) else (pos < kv_len)[None, :]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)

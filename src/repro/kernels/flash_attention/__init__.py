from .ops import decode_attention, flash_attention
from .kernel import flash_attention_pallas
from .ref import decode_attention_ref, flash_attention_ref

__all__ = ["decode_attention", "decode_attention_ref", "flash_attention",
           "flash_attention_pallas", "flash_attention_ref"]

"""Pallas TPU flash-attention forward kernel (causal, GQA).

Grid (B, H, num_q_blocks, num_kv_blocks); the kv axis is the innermost
(sequential on TPU), so the online-softmax running state (m, l, acc) lives in
VMEM scratch and persists across kv steps.  GQA is expressed in the k/v
``index_map`` (kv head = q head // groups) — no host-side repeat.

Block shapes are MXU-aligned (q/kv tiles multiples of 128 on the contracting
dim, head_dim itself 64/128).  VMEM footprint per step:
  q (Bq, hd) bf16 + k,v (Bk, hd) bf16 + acc (Bq, hd) f32 + m,l (Bq,) f32
≈ 0.8 MB at Bq=Bk=512, hd=128 — well inside the ~16 MB VMEM budget.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, block_q: int, block_kv: int, causal: bool,
                  q_offset: int, kv_valid: int, num_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = k_pos < kv_valid
    if causal:
        mask = mask & (q_pos >= k_pos)

    # skip fully-masked blocks (above the causal diagonal)
    run = (not causal) or True

    @pl.when(jnp.any(mask))
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (Bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (Bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (Bq, Bk)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_ref[...] = m_new

    @pl.when(ki == num_kv - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, q_offset: int = 0,
                           block_q: int = 512, block_kv: int = 512,
                           softmax_scale=None,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd)."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    groups = H // KV
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    kv_valid = Skv
    if Sq % block_q:
        raise ValueError(f"Sq={Sq} not divisible by block_q={block_q}")
    if Skv % block_kv:
        pad = block_kv - Skv % block_kv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Skv += pad
    nq, nk = Sq // block_q, Skv // block_kv

    qt = q.transpose(0, 2, 1, 3)   # (B, H, Sq, hd)
    kt = k.transpose(0, 2, 1, 3)   # (B, KV, Skv, hd)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        causal=causal, q_offset=q_offset, kv_valid=kv_valid, num_kv=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b, h, i, j, g=groups: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b, h, i, j, g=groups: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
